//! Convolution lowering: turning quantized conv layers into bit-serial
//! GEMM on the existing overlay stack.
//!
//! The paper motivates BISMO with quantized neural network inference,
//! and the journal follow-up (Umuroglu et al., 2019) shows convolution
//! layers lowered to bit-serial GEMM dominate end-to-end QNN
//! throughput. This module owns that lowering:
//!
//! * [`ConvSpec`] — the shape and legality rules of one 2-D
//!   convolution (stride / padding / dilation / channels), plus its
//!   lowered [`crate::partition::GemmShape`]s.
//! * [`Tensor`] — the NHWC integer activation tensor; chosen so the
//!   lowered GEMM result *is* the output tensor (no per-element
//!   reshape).
//! * [`LoweringMode`] — im2col (one wide GEMM per layer) vs kn2row
//!   (`kh·kw` narrow GEMMs per layer whose products sum); see
//!   `DESIGN.md` §9 for the tradeoff.
//! * [`pack_im2col`] / [`pack_kn2row_tap`] — the zero-materialization
//!   packed paths: bit-planes are built *directly from the input
//!   tensor* via [`crate::bitmatrix::BitSerialMatrix::from_int_fn`],
//!   so the `kh·kw`-times-inflated dense patch matrix never exists on
//!   the hot path. The packed operand enters the serving layer through
//!   [`crate::coordinator::BismoService::submit_lowered`].
//! * [`conv2d_direct`] — the naive `i64` direct-convolution oracle the
//!   whole lowering stack is property-tested against
//!   (`rust/tests/conv_lowering.rs`).
//!
//! Layering: `lowering` sits beside `partition` (it depends only on
//! `bitmatrix` / `partition` / `api::BismoError` / `util`); the
//! serving layer and the [`crate::api::ConvBuilder`] facade consume it
//! from above.

mod conv;
mod lower;
mod tensor;

pub use conv::{conv2d_direct, ConvSpec};
pub use lower::{
    im2col_matrix, kn2row_tap_weights, pack_im2col, pack_kn2row_tap, patch_value, LoweringMode,
};
pub use tensor::Tensor;
