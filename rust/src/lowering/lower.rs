//! Lowering proper: im2col and kn2row views of a convolution as
//! bit-serial GEMM operands.
//!
//! Both modes share one patch sampler ([`patch_value`]), so the dense
//! reference matrix ([`im2col_matrix`]) and the packed hot path
//! ([`pack_im2col`]) cannot disagree: the packed path feeds the same
//! sampler straight into [`BitSerialMatrix::from_int_fn`], building
//! bit-planes directly from the input tensor without ever allocating
//! the `kh·kw`-times-larger dense patch matrix.

use super::conv::ConvSpec;
use super::tensor::Tensor;
use crate::bitmatrix::{BitSerialMatrix, IntMatrix};

/// How a convolution lowers onto the GEMM stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoweringMode {
    /// One `(batch·oh·ow) × (kh·kw·in_c) × out_c` GEMM over the
    /// (virtually sampled) patch matrix. One request per layer; the
    /// widest `k` the stack sees.
    Im2col,
    /// `kh·kw` independent `(batch·oh·ow) × in_c × out_c` GEMMs — one
    /// per kernel tap — whose products sum. No patch duplication at
    /// all; many small concurrent requests instead of one wide one.
    Kn2row,
}

impl LoweringMode {
    /// Stable lowercase name (CLI flag value / JSON field).
    pub fn name(&self) -> &'static str {
        match self {
            LoweringMode::Im2col => "im2col",
            LoweringMode::Kn2row => "kn2row",
        }
    }
}

/// One element of the (virtual) im2col patch matrix: row `p` indexes
/// `(batch, oy, ox)`, column `q` indexes `(r, s, ci)`; out-of-bounds
/// samples are the zero padding.
#[inline]
pub fn patch_value(input: &Tensor, spec: &ConvSpec, p: usize, q: usize) -> i64 {
    let per_img = spec.out_h() * spec.out_w();
    let b = p / per_img;
    let rem = p % per_img;
    let (oy, ox) = (rem / spec.out_w(), rem % spec.out_w());
    let r = q / (spec.kw * spec.in_c);
    let rem = q % (spec.kw * spec.in_c);
    let (s, ci) = (rem / spec.in_c, rem % spec.in_c);
    shifted_value(input, spec, b, oy, ox, r, s, ci)
}

/// Input sample for output position `(oy, ox)` at kernel tap `(r, s)`,
/// channel `ci` — zero where the tap lands in the padding.
#[inline]
#[allow(clippy::too_many_arguments)]
fn shifted_value(
    input: &Tensor,
    spec: &ConvSpec,
    b: usize,
    oy: usize,
    ox: usize,
    r: usize,
    s: usize,
    ci: usize,
) -> i64 {
    let iy = (oy * spec.stride.0 + r * spec.dilation.0) as i64 - spec.pad.0 as i64;
    let ix = (ox * spec.stride.1 + s * spec.dilation.1) as i64 - spec.pad.1 as i64;
    if iy < 0 || ix < 0 || iy >= spec.in_h as i64 || ix >= spec.in_w as i64 {
        0
    } else {
        input.get(b, iy as usize, ix as usize, ci)
    }
}

/// The dense im2col patch matrix, materialized — the reference the
/// packed path is tested against (and a debugging aid). The serving
/// path never builds this; use [`pack_im2col`] there.
pub fn im2col_matrix(input: &Tensor, spec: &ConvSpec) -> IntMatrix {
    let shape = spec.gemm_shape(input.n);
    IntMatrix::from_fn(shape.m, shape.k, |p, q| patch_value(input, spec, p, q))
}

/// Bit-plane-decompose the im2col patch matrix directly from the input
/// tensor: exactly `BitSerialMatrix::from_int(&im2col_matrix(..))`
/// without the dense intermediate. This is the conv hot path's LHS —
/// it goes straight into
/// [`crate::coordinator::BismoService::submit_lowered`]. Panics if an
/// input entry does not fit the precision; callers range-check the
/// (much smaller) input tensor first.
pub fn pack_im2col(input: &Tensor, spec: &ConvSpec, bits: u32, signed: bool) -> BitSerialMatrix {
    let shape = spec.gemm_shape(input.n);
    BitSerialMatrix::from_int_fn(shape.m, shape.k, bits, signed, |p, q| {
        patch_value(input, spec, p, q)
    })
}

/// Bit-plane-decompose the kn2row shifted-activation matrix for kernel
/// tap `(r, s)`: `(batch·oh·ow) × in_c`, sampling the input at that
/// tap's offset (zero in the padding). Like [`pack_im2col`], no dense
/// intermediate.
pub fn pack_kn2row_tap(
    input: &Tensor,
    spec: &ConvSpec,
    r: usize,
    s: usize,
    bits: u32,
    signed: bool,
) -> BitSerialMatrix {
    let shape = spec.kn2row_shape(input.n);
    let per_img = spec.out_h() * spec.out_w();
    BitSerialMatrix::from_int_fn(shape.m, shape.k, bits, signed, |p, ci| {
        let b = p / per_img;
        let rem = p % per_img;
        shifted_value(input, spec, b, rem / spec.out_w(), rem % spec.out_w(), r, s, ci)
    })
}

/// The `in_c × out_c` weight sub-matrix of kernel tap `(r, s)`: a row
/// slice of the lowered weight matrix ([`ConvSpec::weight_rows`]
/// layout), contiguous by construction.
pub fn kn2row_tap_weights(weights: &IntMatrix, spec: &ConvSpec, r: usize, s: usize) -> IntMatrix {
    let base = (r * spec.kw + s) * spec.in_c;
    IntMatrix::from_fn(spec.in_c, spec.out_c, |ci, co| weights.get(base + ci, co))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lowering::conv2d_direct;
    use crate::util::{property_sweep, Rng};

    fn random_spec(rng: &mut Rng) -> ConvSpec {
        loop {
            let spec = ConvSpec {
                in_h: rng.index(9) + 2,
                in_w: rng.index(9) + 2,
                in_c: rng.index(4) + 1,
                out_c: rng.index(5) + 1,
                kh: rng.index(3) + 1,
                kw: rng.index(3) + 1,
                stride: (rng.index(3) + 1, rng.index(3) + 1),
                pad: (rng.index(2), rng.index(2)),
                dilation: (rng.index(2) + 1, rng.index(2) + 1),
            };
            if spec.validate().is_ok() {
                return spec;
            }
        }
    }

    #[test]
    fn im2col_times_weights_equals_direct_conv() {
        property_sweep(0xC0117, 25, |rng, _| {
            let spec = random_spec(rng);
            let batch = rng.index(3) + 1;
            let x = Tensor::random(rng, batch, spec.in_h, spec.in_w, spec.in_c, 3, false);
            let w = spec.weights_from_fn(|_, _, _, _| rng.operand(3, true));
            let patches = im2col_matrix(&x, &spec);
            let want = conv2d_direct(&x, &w, &spec);
            let prod = patches.matmul(&w);
            let got = Tensor::from_gemm_rows(&prod, batch, spec.out_h(), spec.out_w());
            assert_eq!(got, want, "{spec:?}");
        });
    }

    #[test]
    fn packed_im2col_equals_materialize_then_pack() {
        property_sweep(0x9AC2ED, 20, |rng, _| {
            let spec = random_spec(rng);
            let batch = rng.index(3) + 1;
            let bits = rng.index(4) as u32 + 1;
            let x = Tensor::random(rng, batch, spec.in_h, spec.in_w, spec.in_c, bits, false);
            let packed = pack_im2col(&x, &spec, bits, false);
            let dense = im2col_matrix(&x, &spec);
            assert_eq!(packed, BitSerialMatrix::from_int(&dense, bits, false), "{spec:?}");
        });
    }

    #[test]
    fn kn2row_taps_sum_to_direct_conv() {
        property_sweep(0x4273, 20, |rng, _| {
            let spec = random_spec(rng);
            let batch = rng.index(2) + 1;
            let x = Tensor::random(rng, batch, spec.in_h, spec.in_w, spec.in_c, 2, false);
            let w = spec.weights_from_fn(|_, _, _, _| rng.operand(3, true));
            let shape = spec.kn2row_shape(batch);
            let mut acc = IntMatrix::zeros(shape.m, shape.n);
            for r in 0..spec.kh {
                for s in 0..spec.kw {
                    let lhs = pack_kn2row_tap(&x, &spec, r, s, 2, false).to_int();
                    let part = lhs.matmul(&kn2row_tap_weights(&w, &spec, r, s));
                    for i in 0..shape.m {
                        for j in 0..shape.n {
                            acc.set(i, j, acc.get(i, j) + part.get(i, j));
                        }
                    }
                }
            }
            let want = conv2d_direct(&x, &w, &spec);
            let got = Tensor::from_gemm_rows(&acc, batch, spec.out_h(), spec.out_w());
            assert_eq!(got, want, "{spec:?}");
        });
    }
}
