//! [`ConvSpec`]: the shape of one 2-D convolution, its validation
//! rules, and the naive direct-convolution oracle every lowered path
//! is tested against.

use super::tensor::Tensor;
use crate::api::BismoError;
use crate::bitmatrix::IntMatrix;
use crate::partition::GemmShape;

/// One 2-D convolution layer: `in_c → out_c` channels through a
/// `kh × kw` kernel with per-axis stride, zero padding and dilation.
/// Input tensors are NHWC ([`Tensor`]); weights are carried in the
/// *lowered* layout (see [`ConvSpec::weight_rows`]) so the same matrix
/// feeds every lowering mode and the oracle without reshuffling.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvSpec {
    /// Input height and width.
    pub in_h: usize,
    pub in_w: usize,
    /// Input and output channel counts.
    pub in_c: usize,
    pub out_c: usize,
    /// Kernel height and width.
    pub kh: usize,
    pub kw: usize,
    /// Stride `(vertical, horizontal)`.
    pub stride: (usize, usize),
    /// Zero padding `(vertical, horizontal)`, applied symmetrically.
    pub pad: (usize, usize),
    /// Dilation `(vertical, horizontal)`.
    pub dilation: (usize, usize),
}

impl ConvSpec {
    /// A stride-1, dilation-1 spec — the common case.
    pub fn simple(
        in_h: usize,
        in_w: usize,
        in_c: usize,
        out_c: usize,
        k: usize,
        pad: usize,
    ) -> Self {
        ConvSpec {
            in_h,
            in_w,
            in_c,
            out_c,
            kh: k,
            kw: k,
            stride: (1, 1),
            pad: (pad, pad),
            dilation: (1, 1),
        }
    }

    /// Dilated kernel extent along one axis: `(k−1)·d + 1`.
    fn extent(k: usize, d: usize) -> usize {
        (k - 1) * d + 1
    }

    /// The spec-level legality gate shared by every lowering entry
    /// point ([`crate::api::ConvBuilder::build`] runs it before any
    /// work is queued). All-typed-error: zero channels / kernels /
    /// strides / dilations, padding at or beyond the dilated kernel
    /// extent (an output column made entirely of padding), and empty
    /// outputs are all [`BismoError::InvalidConfig`].
    pub fn validate(&self) -> Result<(), BismoError> {
        let err = |m: String| Err(BismoError::InvalidConfig(m));
        if self.in_c == 0 || self.out_c == 0 {
            return err(format!(
                "conv channels must be >= 1, got in_c={} out_c={}",
                self.in_c, self.out_c
            ));
        }
        if self.kh == 0 || self.kw == 0 {
            return err(format!("conv kernel must be >= 1x1, got {}x{}", self.kh, self.kw));
        }
        if self.in_h == 0 || self.in_w == 0 {
            return err(format!("conv input must be >= 1x1, got {}x{}", self.in_h, self.in_w));
        }
        if self.stride.0 == 0 || self.stride.1 == 0 {
            return err(format!("conv stride must be >= 1, got {:?}", self.stride));
        }
        if self.dilation.0 == 0 || self.dilation.1 == 0 {
            return err(format!("conv dilation must be >= 1, got {:?}", self.dilation));
        }
        let (eh, ew) = (
            Self::extent(self.kh, self.dilation.0),
            Self::extent(self.kw, self.dilation.1),
        );
        if self.pad.0 >= eh || self.pad.1 >= ew {
            return err(format!(
                "conv padding {:?} must stay below the dilated kernel extent {}x{}",
                self.pad, eh, ew
            ));
        }
        if self.in_h + 2 * self.pad.0 < eh || self.in_w + 2 * self.pad.1 < ew {
            return err(format!(
                "conv output is empty: padded input {}x{} smaller than dilated kernel {}x{}",
                self.in_h + 2 * self.pad.0,
                self.in_w + 2 * self.pad.1,
                eh,
                ew
            ));
        }
        Ok(())
    }

    /// Output height (assumes [`ConvSpec::validate`] passed).
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad.0 - Self::extent(self.kh, self.dilation.0)) / self.stride.0 + 1
    }

    /// Output width (assumes [`ConvSpec::validate`] passed).
    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad.1 - Self::extent(self.kw, self.dilation.1)) / self.stride.1 + 1
    }

    /// Rows of the lowered weight matrix: `kh·kw·in_c`. Row index
    /// `(r·kw + s)·in_c + ci` holds the weight for kernel offset
    /// `(r, s)` and input channel `ci`; columns are output channels —
    /// exactly the RHS layout of the im2col GEMM, and the layout
    /// kn2row slices its per-tap sub-matrices out of.
    pub fn weight_rows(&self) -> usize {
        self.kh * self.kw * self.in_c
    }

    /// Shape of the im2col-lowered GEMM for a `batch`-image input:
    /// `(batch·out_h·out_w) × (kh·kw·in_c) × out_c`.
    pub fn gemm_shape(&self, batch: usize) -> GemmShape {
        GemmShape {
            m: batch * self.out_h() * self.out_w(),
            k: self.weight_rows(),
            n: self.out_c,
        }
    }

    /// Shape of *one* kn2row tap GEMM: same output rows, but `k` is
    /// only `in_c` — the kernel spatial extent becomes `kh·kw`
    /// separate GEMMs whose products sum.
    pub fn kn2row_shape(&self, batch: usize) -> GemmShape {
        GemmShape {
            m: batch * self.out_h() * self.out_w(),
            k: self.in_c,
            n: self.out_c,
        }
    }

    /// Validate that `input` matches this spec's geometry.
    pub fn check_input(&self, input: &Tensor) -> Result<(), BismoError> {
        if input.h != self.in_h || input.w != self.in_w || input.c != self.in_c {
            return Err(BismoError::ShapeMismatch(format!(
                "conv input {}x{}x{} does not match spec {}x{}x{}",
                input.h, input.w, input.c, self.in_h, self.in_w, self.in_c
            )));
        }
        if input.n == 0 {
            return Err(BismoError::ShapeMismatch("conv input batch is empty".into()));
        }
        Ok(())
    }

    /// Validate that `weights` is the lowered `weight_rows() × out_c`
    /// matrix this spec expects.
    pub fn check_weights(&self, weights: &IntMatrix) -> Result<(), BismoError> {
        if weights.rows != self.weight_rows() || weights.cols != self.out_c {
            return Err(BismoError::ShapeMismatch(format!(
                "conv weights {}x{} do not match lowered layout {}x{} (kh·kw·in_c × out_c)",
                weights.rows,
                weights.cols,
                self.weight_rows(),
                self.out_c
            )));
        }
        Ok(())
    }

    /// Build a lowered weight matrix from a function of
    /// `(out_channel, kernel_row, kernel_col, in_channel)`.
    pub fn weights_from_fn<F: FnMut(usize, usize, usize, usize) -> i64>(
        &self,
        mut f: F,
    ) -> IntMatrix {
        IntMatrix::from_fn(self.weight_rows(), self.out_c, |row, co| {
            let r = row / (self.kw * self.in_c);
            let rem = row % (self.kw * self.in_c);
            f(co, r, rem / self.in_c, rem % self.in_c)
        })
    }
}

/// Naive direct convolution in `i64` — the correctness oracle every
/// lowered path (im2col, kn2row, packed, sharded, cached) is
/// property-tested against. Deliberately the obvious sextuple loop;
/// no lowering machinery is shared with the paths under test.
pub fn conv2d_direct(input: &Tensor, weights: &IntMatrix, spec: &ConvSpec) -> Tensor {
    spec.check_input(input).expect("input matches spec");
    spec.check_weights(weights).expect("weights match spec");
    let (oh, ow) = (spec.out_h(), spec.out_w());
    let mut out = Tensor::zeros(input.n, oh, ow, spec.out_c);
    for b in 0..input.n {
        for oy in 0..oh {
            for ox in 0..ow {
                for co in 0..spec.out_c {
                    let mut acc = 0i64;
                    for r in 0..spec.kh {
                        let iy = (oy * spec.stride.0 + r * spec.dilation.0) as i64
                            - spec.pad.0 as i64;
                        if iy < 0 || iy >= spec.in_h as i64 {
                            continue;
                        }
                        for s in 0..spec.kw {
                            let ix = (ox * spec.stride.1 + s * spec.dilation.1) as i64
                                - spec.pad.1 as i64;
                            if ix < 0 || ix >= spec.in_w as i64 {
                                continue;
                            }
                            for ci in 0..spec.in_c {
                                acc += input.get(b, iy as usize, ix as usize, ci)
                                    * weights.get((r * spec.kw + s) * spec.in_c + ci, co);
                            }
                        }
                    }
                    out.set(b, oy, ox, co, acc);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn output_dims_match_the_textbook_formula() {
        let spec = ConvSpec::simple(28, 28, 1, 8, 3, 1);
        assert_eq!((spec.out_h(), spec.out_w()), (28, 28));
        let strided = ConvSpec {
            stride: (2, 2),
            pad: (0, 0),
            ..spec
        };
        assert_eq!((strided.out_h(), strided.out_w()), (13, 13));
        let dilated = ConvSpec {
            dilation: (2, 2),
            pad: (2, 2),
            ..spec
        };
        assert_eq!((dilated.out_h(), dilated.out_w()), (28, 28));
    }

    #[test]
    fn illegal_specs_are_typed_errors() {
        let ok = ConvSpec::simple(8, 8, 3, 4, 3, 1);
        assert!(ok.validate().is_ok());
        // Zero channels.
        let r = ConvSpec { in_c: 0, ..ok }.validate();
        assert!(matches!(r, Err(BismoError::InvalidConfig(_))), "{r:?}");
        let r = ConvSpec { out_c: 0, ..ok }.validate();
        assert!(matches!(r, Err(BismoError::InvalidConfig(_))), "{r:?}");
        // Padding at/beyond the kernel extent.
        let r = ConvSpec { pad: (3, 1), ..ok }.validate();
        assert!(matches!(r, Err(BismoError::InvalidConfig(_))), "{r:?}");
        // ... measured against the *dilated* extent: pad 3 is legal for
        // a dilated 3x3 (extent 5), illegal undilated.
        let dil = ConvSpec {
            pad: (3, 3),
            dilation: (2, 2),
            ..ok
        };
        assert!(dil.validate().is_ok());
        // Degenerate axes.
        let mut zero_stride = ok;
        zero_stride.stride = (0, 1);
        let mut zero_dilation = ok;
        zero_dilation.dilation = (1, 0);
        let degenerate = [
            ConvSpec { kh: 0, ..ok },
            ConvSpec { in_h: 0, ..ok },
            zero_stride,
            zero_dilation,
        ];
        for bad in degenerate {
            assert!(matches!(bad.validate(), Err(BismoError::InvalidConfig(_))));
        }
        // Kernel larger than the padded input: empty output.
        let r = ConvSpec::simple(2, 2, 1, 1, 5, 1).validate();
        assert!(matches!(r, Err(BismoError::InvalidConfig(_))), "{r:?}");
    }

    #[test]
    fn direct_conv_identity_kernel_is_identity() {
        // 1x1 kernel, identity weights: output == input per channel.
        let mut rng = Rng::new(0x1D);
        let spec = ConvSpec::simple(5, 4, 3, 3, 1, 0);
        let x = Tensor::random(&mut rng, 2, 5, 4, 3, 3, false);
        let w = spec.weights_from_fn(|co, _, _, ci| (co == ci) as i64);
        let y = conv2d_direct(&x, &w, &spec);
        assert_eq!(y, x);
    }

    #[test]
    fn direct_conv_matches_hand_computed_example() {
        // 1 image, 3x3 input, one channel, 2x2 kernel of ones, no pad:
        // each output is the sum of a 2x2 window.
        let x = Tensor::from_fn(1, 3, 3, 1, |_, y, xp, _| (y * 3 + xp) as i64);
        let spec = ConvSpec::simple(3, 3, 1, 1, 2, 0);
        let w = spec.weights_from_fn(|_, _, _, _| 1);
        let y = conv2d_direct(&x, &w, &spec);
        assert_eq!(y.get(0, 0, 0, 0), 8); // window {0,1,3,4}
        assert_eq!(y.get(0, 1, 1, 0), 24); // window {4,5,7,8}
        assert_eq!((y.h, y.w), (2, 2));
    }

    #[test]
    fn weights_from_fn_uses_the_lowered_row_order() {
        let spec = ConvSpec::simple(4, 4, 2, 3, 2, 0);
        let w = spec.weights_from_fn(|co, r, s, ci| (co * 1000 + r * 100 + s * 10 + ci) as i64);
        // Row (r·kw + s)·in_c + ci with r=1, s=0, ci=1, column co=2.
        assert_eq!(w.get(5, 2), 2101);
        assert_eq!(w.rows, spec.weight_rows());
    }
}
