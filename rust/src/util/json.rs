//! Minimal JSON parser *and writer* (no serde offline).
//!
//! Supports the full JSON grammar minus exotic number forms; ample for
//! `artifacts/manifest.json`, `BENCH_*.json` emission and small config
//! files. Not a streaming parser; inputs are small.

use std::collections::BTreeMap;

/// Parse failure from [`Json::parse`]: the message carries the byte
/// offset and what was expected. Convertible into
/// [`crate::api::BismoError::Parse`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct JsonError(pub String);

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json: {}", self.0)
    }
}

impl std::error::Error for JsonError {}

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value().map_err(JsonError)?;
        p.ws();
        if p.i != p.b.len() {
            return Err(JsonError(format!("trailing garbage at byte {}", p.i)));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience constructor: a string value.
    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    /// Convenience constructor: a number value.
    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// Serialize compactly (no whitespace). `parse(dump(v)) == v` for
    /// every value whose numbers survive an f64 round trip.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize human-readably with `indent`-space nesting.
    pub fn pretty(&self, indent: usize) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(indent), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let pad = |out: &mut String, d: usize| {
            if let Some(n) = indent {
                out.push('\n');
                out.push_str(&" ".repeat(n * d));
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => out.push_str(&fmt_number(*n)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    pad(out, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, item)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    item.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    pad(out, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Format a JSON number: integers without a fractional part, everything
/// else with enough digits to round-trip.
fn fmt_number(n: f64) -> String {
    if !n.is_finite() {
        // JSON has no Inf/NaN; emit `null` so a degenerate metric reads
        // as missing downstream instead of masquerading as a real zero.
        return "null".to_string();
    }
    if n.fract() == 0.0 && n.abs() < 9.007_199_254_740_992e15 {
        format!("{}", n as i64)
    } else {
        format!("{n}")
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl std::fmt::Display for Json {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.dump())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or("short \\u escape")?,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // Copy a UTF-8 sequence verbatim.
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let s = std::str::from_utf8(&self.b[self.i..self.i + len])
                        .map_err(|_| "bad utf8")?;
                    out.push_str(s);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected , or ] but got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => return Err(format!("expected , or }} but got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(
            r#"{"m": {"file": "m.hlo.txt", "inputs": [{"shape": [8, 16], "dtype": "int32"}]}}"#,
        )
        .unwrap();
        let entry = j.get("m").unwrap();
        assert_eq!(entry.get("file").unwrap().as_str(), Some("m.hlo.txt"));
        let inputs = entry.get("inputs").unwrap().as_arr().unwrap();
        let shape = inputs[0].get("shape").unwrap().as_arr().unwrap();
        assert_eq!(shape[0].as_usize(), Some(8));
        assert_eq!(shape[1].as_usize(), Some(16));
    }

    #[test]
    fn scalars_and_nesting() {
        let j = Json::parse(r#"[1, -2.5, true, false, null, "a\nb", {"x": []}]"#).unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[1].as_f64(), Some(-2.5));
        assert_eq!(a[2], Json::Bool(true));
        assert_eq!(a[4], Json::Null);
        assert_eq!(a[5].as_str(), Some("a\nb"));
        assert!(a[6].get("x").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn dump_roundtrips() {
        let src = r#"{"a": [1, -2.5, true, false, null, "x\ny"], "b": {"c": 0.125}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
        assert_eq!(Json::parse(&j.pretty(2)).unwrap(), j);
    }

    #[test]
    fn dump_integers_without_fraction() {
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(-3.0).dump(), "-3");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
        assert_eq!(Json::Num(1.5e20).dump(), "150000000000000000000");
    }

    #[test]
    fn non_finite_numbers_dump_as_null() {
        // JSON has no Inf/NaN; a degenerate metric must read as missing,
        // not as a legitimate zero.
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
        assert_eq!(Json::parse(&Json::Num(f64::NAN).dump()).unwrap(), Json::Null);
    }

    #[test]
    fn dump_escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.dump(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn pretty_is_indented() {
        let j = Json::parse(r#"{"k": [1, 2]}"#).unwrap();
        let p = j.pretty(2);
        assert!(p.contains("\n  \"k\""), "{p}");
        assert!(p.ends_with('}'));
    }

    #[test]
    fn empty_containers_stay_compact() {
        assert_eq!(Json::Arr(vec![]).pretty(2), "[]");
        assert_eq!(Json::Obj(Default::default()).pretty(2), "{}");
    }
}
