//! Tiny CSV writer used by the benchmark harness to dump figure/table
//! data for external plotting. No quoting edge-cases are needed: all our
//! emitted fields are numbers or simple identifiers (asserted).

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Accumulates rows and writes them to `results/<name>.csv`.
pub struct CsvWriter {
    path: PathBuf,
    buf: String,
    cols: usize,
}

impl CsvWriter {
    /// Create a writer with a header row. The file is written on
    /// [`CsvWriter::finish`].
    pub fn new<P: AsRef<Path>>(path: P, header: &[&str]) -> Self {
        let mut buf = String::new();
        for (i, h) in header.iter().enumerate() {
            assert!(is_simple(h), "CSV header field needs no quoting: {h:?}");
            if i > 0 {
                buf.push(',');
            }
            buf.push_str(h);
        }
        buf.push('\n');
        CsvWriter {
            path: path.as_ref().to_path_buf(),
            buf,
            cols: header.len(),
        }
    }

    /// Append one row of already-formatted fields.
    pub fn row(&mut self, fields: &[String]) {
        assert_eq!(
            fields.len(),
            self.cols,
            "row width mismatch in {:?}",
            self.path
        );
        for (i, f) in fields.iter().enumerate() {
            assert!(is_simple(f), "CSV field needs no quoting: {f:?}");
            if i > 0 {
                self.buf.push(',');
            }
            self.buf.push_str(f);
        }
        self.buf.push('\n');
    }

    /// Convenience: append a row of display-formatted values.
    pub fn rowf(&mut self, fields: &[&dyn std::fmt::Display]) {
        let v: Vec<String> = fields.iter().map(|f| {
            let mut s = String::new();
            let _ = write!(s, "{f}");
            s
        }).collect();
        self.row(&v);
    }

    /// Write the accumulated contents, creating parent dirs.
    pub fn finish(self) -> io::Result<PathBuf> {
        if let Some(parent) = self.path.parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(&self.path, self.buf.as_bytes())?;
        Ok(self.path)
    }
}

fn is_simple(s: &str) -> bool {
    !s.contains(',') && !s.contains('"') && !s.contains('\n')
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_csv() {
        let dir = std::env::temp_dir().join("bismo_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::new(&path, &["a", "b"]);
        w.row(&["1".into(), "2.5".into()]);
        w.rowf(&[&3, &4.5]);
        let p = w.finish().unwrap();
        let body = std::fs::read_to_string(p).unwrap();
        assert_eq!(body, "a,b\n1,2.5\n3,4.5\n");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut w = CsvWriter::new("/tmp/x.csv", &["a", "b"]);
        w.row(&["1".into()]);
    }
}
