//! Small self-contained utilities: deterministic PRNG, property-sweep
//! helper, timing, and CSV emission.
//!
//! The offline crate registry for this build provides no `rand`,
//! `proptest`, `criterion` or `serde`, so the handful of primitives the
//! rest of the crate needs live here.

pub mod bench;
pub mod csv;
pub mod json;
pub mod rng;

pub use bench::{BenchTimer, Samples};
pub use csv::CsvWriter;
pub use json::{Json, JsonError};
pub use rng::Rng;

/// splitmix64 finalizer: one full-avalanche mixing round. Shared by the
/// PRNG's seed expansion ([`Rng::new`]) and the packing cache's content
/// hash ([`crate::bitmatrix::IntMatrix::content_hash`]) so the mixer
/// constants live in exactly one place.
#[inline]
pub fn splitmix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Integer ceiling division. Used throughout the timing and cost models
/// (`ceil(k / D_k)` chunks, `ceil(B_m / 1024)` BRAM tiles, ...).
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    debug_assert!(b > 0);
    (a + b - 1) / b
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: u64, b: u64) -> u64 {
    ceil_div(a, b) * b
}

/// `ceil(log2(x))` for `x >= 1`; 0 for `x == 1`.
#[inline]
pub fn ceil_log2(x: u64) -> u32 {
    debug_assert!(x >= 1);
    if x <= 1 {
        0
    } else {
        64 - (x - 1).leading_zeros()
    }
}

/// Run `f` over `n` pseudo-random cases derived from `seed`. This is the
/// crate's stand-in for a property-based testing harness: no shrinking,
/// but deterministic and seed-reportable. On failure the closure should
/// panic with enough context (the case index is added by this wrapper).
pub fn property_sweep<F: FnMut(&mut Rng, usize)>(seed: u64, n: usize, mut f: F) {
    for case in 0..n {
        let mut rng = Rng::new(seed ^ (0x9e37_79b9_7f4a_7c15u64.wrapping_mul(case as u64 + 1)));
        f(&mut rng, case);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(0, 4), 0);
        assert_eq!(ceil_div(1, 4), 1);
        assert_eq!(ceil_div(4, 4), 1);
        assert_eq!(ceil_div(5, 4), 2);
        assert_eq!(ceil_div(1024, 1024), 1);
        assert_eq!(ceil_div(1025, 1024), 2);
    }

    #[test]
    fn round_up_basics() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn ceil_log2_basics() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(1024), 10);
        assert_eq!(ceil_log2(1025), 11);
    }

    #[test]
    fn property_sweep_is_deterministic() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        property_sweep(42, 5, |rng, _| a.push(rng.next_u64()));
        property_sweep(42, 5, |rng, _| b.push(rng.next_u64()));
        assert_eq!(a, b);
        assert_eq!(a.len(), 5);
    }
}
