//! Minimal benchmark support (the offline registry has no criterion).
//!
//! `cargo bench` targets in this crate use `harness = false` and drive
//! [`BenchTimer`] directly: warmup, then timed iterations until both a
//! minimum sample count and a minimum wall-clock budget are met, then
//! robust statistics over the per-iteration samples.

use std::time::{Duration, Instant};

/// Summary statistics over per-iteration timings (nanoseconds).
#[derive(Clone, Debug)]
pub struct Samples {
    /// Sorted per-iteration durations in nanoseconds.
    pub ns: Vec<f64>,
}

impl Samples {
    pub fn median(&self) -> f64 {
        percentile_sorted(&self.ns, 50.0)
    }
    pub fn p05(&self) -> f64 {
        percentile_sorted(&self.ns, 5.0)
    }
    pub fn p95(&self) -> f64 {
        percentile_sorted(&self.ns, 95.0)
    }
    pub fn mean(&self) -> f64 {
        self.ns.iter().sum::<f64>() / self.ns.len() as f64
    }
    pub fn min(&self) -> f64 {
        *self.ns.first().unwrap()
    }
    pub fn max(&self) -> f64 {
        *self.ns.last().unwrap()
    }
    /// Arbitrary percentile in `[0, 100]` (nearest-rank on the sorted
    /// samples) — the latency-distribution accessor `serve-bench` uses
    /// for its p50/p90/p99 report.
    pub fn percentile(&self, p: f64) -> f64 {
        percentile_sorted(&self.ns, p)
    }
}

fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let idx = (p / 100.0 * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Bench runner: measures a closure with warmup and a time budget.
pub struct BenchTimer {
    /// Minimum number of timed samples.
    pub min_samples: usize,
    /// Minimum total measurement time.
    pub min_time: Duration,
    /// Warmup time before measurement starts.
    pub warmup: Duration,
}

impl Default for BenchTimer {
    fn default() -> Self {
        BenchTimer {
            min_samples: 10,
            min_time: Duration::from_millis(300),
            warmup: Duration::from_millis(100),
        }
    }
}

impl BenchTimer {
    /// Quick preset for heavyweight benchmarks (seconds per iteration).
    pub fn heavy() -> Self {
        BenchTimer {
            min_samples: 3,
            min_time: Duration::from_millis(200),
            warmup: Duration::from_millis(0),
        }
    }

    /// Smoke-test preset (`bismo bench --quick`, CI): one warm sample —
    /// enough to validate the harness and produce a schema-complete
    /// report, not enough for stable statistics.
    pub fn smoke() -> Self {
        BenchTimer {
            min_samples: 1,
            min_time: Duration::from_millis(10),
            warmup: Duration::from_millis(5),
        }
    }

    /// Measure `f`, returning sorted per-iteration samples. The closure's
    /// return value is passed through `std::hint::black_box` to keep the
    /// optimizer honest.
    pub fn run<T, F: FnMut() -> T>(&self, mut f: F) -> Samples {
        let warm_start = Instant::now();
        while warm_start.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        let mut ns = Vec::new();
        let start = Instant::now();
        while ns.len() < self.min_samples || start.elapsed() < self.min_time {
            let t0 = Instant::now();
            std::hint::black_box(f());
            ns.push(t0.elapsed().as_nanos() as f64);
            if ns.len() >= 1_000_000 {
                break;
            }
        }
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Samples { ns }
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Print one bench result line in a stable, grep-friendly format.
pub fn report(name: &str, s: &Samples, throughput: Option<(f64, &str)>) {
    let med = s.median();
    let extra = match throughput {
        Some((units_per_iter, unit)) => {
            let per_sec = units_per_iter / (med / 1e9);
            format!("  {:>12.3e} {unit}/s", per_sec)
        }
        None => String::new(),
    };
    println!(
        "bench {name:<44} median {:>12}  p05 {:>12}  p95 {:>12}  n={}{}",
        fmt_ns(med),
        fmt_ns(s.p05()),
        fmt_ns(s.p95()),
        s.ns.len(),
        extra
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_sorts() {
        let t = BenchTimer {
            min_samples: 5,
            min_time: Duration::from_millis(1),
            warmup: Duration::from_millis(0),
        };
        let s = t.run(|| (0..100u64).sum::<u64>());
        assert!(s.ns.len() >= 5);
        assert!(s.ns.windows(2).all(|w| w[0] <= w[1]));
        assert!(s.min() <= s.median() && s.median() <= s.p95());
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }

    #[test]
    fn percentile_bounds() {
        let s = Samples {
            ns: vec![1.0, 2.0, 3.0, 4.0, 5.0],
        };
        assert_eq!(s.p05(), 1.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.p95(), 5.0);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.max(), 5.0);
    }
}
