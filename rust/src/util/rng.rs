//! Deterministic xoshiro256**-style PRNG.
//!
//! Used by tests (property sweeps), workload generators and the
//! synthetic-dataset builder. Not cryptographic; chosen for quality of
//! low bits and reproducibility across platforms.

/// A small, fast, deterministic PRNG (xoshiro256**).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a PRNG from a 64-bit seed via splitmix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            super::splitmix64(sm)
        };
        let s = [next(), next(), next(), next()];
        // Avoid the all-zero state (astronomically unlikely, but cheap).
        let s = if s == [0; 4] { [1, 2, 3, 4] } else { s };
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Multiply-shift rejection-free mapping (slight modulo bias is
        // irrelevant at our bounds << 2^64).
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + self.below(span) as i64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        (self.next_u64() as f64 / u64::MAX as f64) < p
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// A random `bits`-wide integer, signed or unsigned — the value domain
    /// of BISMO operands.
    pub fn operand(&mut self, bits: u32, signed: bool) -> i64 {
        debug_assert!(bits >= 1 && bits <= 32);
        if signed {
            let lo = -(1i64 << (bits - 1));
            let hi = (1i64 << (bits - 1)) - 1;
            self.range(lo, hi)
        } else {
            self.range(0, (1i64 << bits) - 1)
        }
    }

    /// Pick an element from a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Rng::new(11);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            let v = r.range(-2, 2);
            assert!((-2..=2).contains(&v));
            lo_seen |= v == -2;
            hi_seen |= v == 2;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn operand_domains() {
        let mut r = Rng::new(13);
        for _ in 0..500 {
            let u = r.operand(4, false);
            assert!((0..16).contains(&u));
            let s = r.operand(4, true);
            assert!((-8..8).contains(&s));
            let b = r.operand(1, false);
            assert!(b == 0 || b == 1);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }
}
