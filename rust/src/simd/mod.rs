//! Runtime-dispatched SIMD strips for the bit-serial datapath.
//!
//! BISMO's performance claim rests on the AND+popcount binary dot
//! product; this module is the software analogue of the journal
//! follow-up's widened datapath (Umuroglu et al., 2019): the inner
//! word-strip primitive and the bit-plane packing loop, each written
//! over explicit SIMD with a portable scalar fallback, selected **once
//! per process** into a [`DispatchTier`].
//!
//! Tiers (best-first): AVX-512 (`vpandq` + `vpopcntq`), AVX2 (`vpand` +
//! Harley–Seal compressor tree over the `vpshufb` nibble popcount),
//! NEON (`cnt` + widening pairwise adds), and the scalar 4-word
//! unrolled strip every other tier is property-tested against.
//!
//! Selection: [`DispatchTier::detect`] picks the best tier the host
//! CPU reports (`is_x86_feature_detected!` /
//! `is_aarch64_feature_detected!`); the `BISMO_SIMD` env var
//! (`auto|avx512|avx2|neon|scalar`) overrides it, so every tier the
//! host supports is testable — the forced-dispatch test matrix in
//! `rust/tests/simd_dispatch.rs` and the CI forced-scalar job both
//! lean on this. Unknown or host-unsupported override values are a
//! typed [`BismoError::InvalidConfig`], never a silent fallback.
//!
//! Every SIMD path is bit-exact with the scalar strip by contract:
//! the packing helpers produce word-identical planes and the popcount
//! strips produce identical sums, across tails (`k` not a multiple of
//! the vector width), single-word rows and all-zero planes. See
//! `DESIGN.md` §11 for the layout rationale.

use crate::api::BismoError;
use std::fmt;
use std::sync::OnceLock;

/// Environment variable that overrides tier selection:
/// `auto|avx512|avx2|neon|scalar`.
pub const ENV_VAR: &str = "BISMO_SIMD";

/// One SIMD implementation tier of the AND+popcount datapath, resolved
/// once per process (see [`DispatchTier::active`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum DispatchTier {
    /// Portable 4-word unrolled `u64::count_ones` strip — the reference
    /// implementation every other tier must match bit-exactly.
    Scalar,
    /// AArch64 NEON: `cnt` byte popcount + widening pairwise adds.
    Neon,
    /// x86-64 AVX2: `vpand` + Harley–Seal carry-save compressor over
    /// the `vpshufb` nibble-LUT popcount.
    Avx2,
    /// x86-64 AVX-512F + AVX-512VPOPCNTDQ: `vpandq` + `vpopcntq`.
    Avx512,
}

impl DispatchTier {
    /// Lower-case tier name, as accepted by `BISMO_SIMD` and reported
    /// in the `simd_tier` field of every BENCH_*.json.
    pub fn name(self) -> &'static str {
        match self {
            DispatchTier::Scalar => "scalar",
            DispatchTier::Neon => "neon",
            DispatchTier::Avx2 => "avx2",
            DispatchTier::Avx512 => "avx512",
        }
    }

    /// Best tier the host CPU supports, ignoring the env override.
    pub fn detect() -> DispatchTier {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512vpopcntdq") {
                return DispatchTier::Avx512;
            }
            if is_x86_feature_detected!("avx2") {
                return DispatchTier::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return DispatchTier::Neon;
            }
        }
        DispatchTier::Scalar
    }

    /// Can this tier execute on the current host?
    pub fn is_available(self) -> bool {
        match self {
            DispatchTier::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            DispatchTier::Avx2 => is_x86_feature_detected!("avx2"),
            #[cfg(target_arch = "x86_64")]
            DispatchTier::Avx512 => {
                is_x86_feature_detected!("avx512f") && is_x86_feature_detected!("avx512vpopcntdq")
            }
            #[cfg(target_arch = "aarch64")]
            DispatchTier::Neon => std::arch::is_aarch64_feature_detected!("neon"),
            _ => false,
        }
    }

    /// Every tier the host can run, scalar first — the axis of the
    /// forced-dispatch differential test matrix. Always non-empty:
    /// scalar runs everywhere.
    pub fn supported() -> Vec<DispatchTier> {
        [
            DispatchTier::Scalar,
            DispatchTier::Neon,
            DispatchTier::Avx2,
            DispatchTier::Avx512,
        ]
        .into_iter()
        .filter(|t| t.is_available())
        .collect()
    }

    /// Parse a `BISMO_SIMD` override value (case-insensitive,
    /// whitespace-trimmed). `Ok(None)` means auto-detect; an unknown
    /// name is a typed error, never a silent fallback.
    pub fn parse_override(value: &str) -> Result<Option<DispatchTier>, BismoError> {
        match value.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => Ok(None),
            "scalar" => Ok(Some(DispatchTier::Scalar)),
            "neon" => Ok(Some(DispatchTier::Neon)),
            "avx2" => Ok(Some(DispatchTier::Avx2)),
            "avx512" => Ok(Some(DispatchTier::Avx512)),
            other => Err(BismoError::InvalidConfig(format!(
                "{ENV_VAR} must be auto|avx512|avx2|neon|scalar, got {other:?}"
            ))),
        }
    }

    /// Read and parse the `BISMO_SIMD` env var. `Ok(None)` when unset
    /// or `auto`.
    pub fn from_env() -> Result<Option<DispatchTier>, BismoError> {
        match std::env::var(ENV_VAR) {
            Ok(v) => Self::parse_override(&v),
            Err(std::env::VarError::NotPresent) => Ok(None),
            Err(std::env::VarError::NotUnicode(_)) => Err(BismoError::InvalidConfig(format!(
                "{ENV_VAR} is not valid UTF-8"
            ))),
        }
    }

    /// The tier this process should run: the `BISMO_SIMD` override when
    /// set (which must name a tier the host actually supports), else
    /// [`DispatchTier::detect`].
    pub fn resolve() -> Result<DispatchTier, BismoError> {
        match Self::from_env()? {
            None => Ok(Self::detect()),
            Some(t) if t.is_available() => Ok(t),
            Some(t) => Err(BismoError::InvalidConfig(format!(
                "{ENV_VAR}={} requested but this host supports only {:?}",
                t.name(),
                Self::supported().iter().map(|s| s.name()).collect::<Vec<_>>()
            ))),
        }
    }

    /// The process-wide tier, resolved once and cached for the life of
    /// the process (the strips are on the innermost hot path; the env
    /// var is not re-read). Panics if the `BISMO_SIMD` override is
    /// invalid — the CLI and the service constructors call
    /// [`DispatchTier::resolve`] first, so user-facing paths report the
    /// typed [`BismoError::InvalidConfig`] instead of panicking.
    pub fn active() -> DispatchTier {
        static ACTIVE: OnceLock<DispatchTier> = OnceLock::new();
        *ACTIVE.get_or_init(|| Self::resolve().unwrap_or_else(|e| panic!("{e}")))
    }
}

impl fmt::Display for DispatchTier {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Binary dot product `Σ popcount(a[i] & b[i])` over equal-length word
/// strips, computed by the strip implementation of `tier`.
///
/// Callers must pass a tier that [`DispatchTier::is_available`] on this
/// host — the public selection paths ([`DispatchTier::active`],
/// [`DispatchTier::resolve`], [`DispatchTier::supported`]) never
/// produce one that isn't. Passing a tier that is compiled in but not
/// supported by the CPU is undefined behavior (illegal instruction);
/// a tier not compiled for this target panics.
#[inline]
pub fn popcount_and_tier(tier: DispatchTier, a: &[u64], b: &[u64]) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(tier.is_available(), "tier {tier} not available on this host");
    match tier {
        DispatchTier::Scalar => popcount_and_scalar(a, b),
        #[cfg(target_arch = "x86_64")]
        DispatchTier::Avx2 => unsafe { x86::popcount_and_avx2(a, b) },
        #[cfg(target_arch = "x86_64")]
        DispatchTier::Avx512 => unsafe { x86::popcount_and_avx512(a, b) },
        #[cfg(target_arch = "aarch64")]
        DispatchTier::Neon => unsafe { neon::popcount_and_neon(a, b) },
        other => panic!("dispatch tier {other} is not compiled into this binary"),
    }
}

/// The portable scalar strip: 4-word unrolled with independent counter
/// chains so the popcounts pipeline instead of serializing on one
/// accumulator. This is the reference implementation every SIMD tier is
/// property-tested against, and the `BISMO_SIMD=scalar` fallback.
#[inline]
pub fn popcount_and_scalar(a: &[u64], b: &[u64]) -> u64 {
    let mut c0 = 0u64;
    let mut c1 = 0u64;
    let mut c2 = 0u64;
    let mut c3 = 0u64;
    let mut astrips = a.chunks_exact(4);
    let mut bstrips = b.chunks_exact(4);
    for (wa, wb) in (&mut astrips).zip(&mut bstrips) {
        c0 += (wa[0] & wb[0]).count_ones() as u64;
        c1 += (wa[1] & wb[1]).count_ones() as u64;
        c2 += (wa[2] & wb[2]).count_ones() as u64;
        c3 += (wa[3] & wb[3]).count_ones() as u64;
    }
    for (&x, &y) in astrips.remainder().iter().zip(bstrips.remainder()) {
        c0 += (x & y).count_ones() as u64;
    }
    c0 + c1 + c2 + c3
}

/// Pack one ≤64-column chunk of row values into per-plane words:
/// `words[p]` receives bit `bi` iff bit `p` of the two's-complement
/// pattern of `vals[bi]` is set (`words.len()` is the operand width in
/// bits). All of `words` is overwritten.
///
/// Returns `false` if any value falls outside `[lo, hi]` — the caller
/// re-walks the chunk scalarly to produce its exact panic message, so
/// the packed output of a failed call is never used.
///
/// Word order is identical across tiers by construction: bit `bi` of a
/// plane word always corresponds to column `chunk_base + bi`, which is
/// exactly the order [`popcount_and_tier`] strips consume. The AVX2
/// packer (also used by the `Avx512` tier) extracts four columns per
/// plane per step via sign-bit movemasks; NEON uses the scalar packer —
/// per-lane bit extraction on NEON costs more than the scalar set-bit
/// walk it would replace.
#[inline]
pub fn pack_chunk(tier: DispatchTier, vals: &[i64], lo: i64, hi: i64, words: &mut [u64]) -> bool {
    debug_assert!(vals.len() <= 64, "chunk wider than one packed word");
    debug_assert!(!words.is_empty() && words.len() <= 32);
    match tier {
        #[cfg(target_arch = "x86_64")]
        DispatchTier::Avx2 | DispatchTier::Avx512 => unsafe {
            x86::pack_chunk_avx2(vals, lo, hi, words)
        },
        _ => pack_chunk_scalar(vals, lo, hi, words),
    }
}

/// Scalar reference packer: per-value range check, then a set-bit walk
/// over the masked two's-complement pattern (cheap for the sparse
/// low-precision operands BISMO targets).
pub fn pack_chunk_scalar(vals: &[i64], lo: i64, hi: i64, words: &mut [u64]) -> bool {
    for w in words.iter_mut() {
        *w = 0;
    }
    let mask = ((1u128 << words.len()) - 1) as u64;
    for (bi, &v) in vals.iter().enumerate() {
        if v < lo || v > hi {
            return false;
        }
        let mut p = (v as u64) & mask;
        while p != 0 {
            words[p.trailing_zeros() as usize] |= 1u64 << bi;
            p &= p - 1;
        }
    }
    true
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// AND two 4-word blocks at word offset `i`.
    ///
    /// # Safety
    /// Requires AVX2; `a.add(i)..a.add(i + 4)` and likewise for `b`
    /// must be readable.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn and_load(a: *const u64, b: *const u64, i: usize) -> __m256i {
        _mm256_and_si256(
            _mm256_loadu_si256(a.add(i) as *const __m256i),
            _mm256_loadu_si256(b.add(i) as *const __m256i),
        )
    }

    /// Per-byte popcount via the `vpshufb` nibble lookup (Muła).
    ///
    /// # Safety
    /// Requires AVX2.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn popcnt_bytes(v: __m256i) -> __m256i {
        // Nibble-indexed popcount table, one 16-byte copy per lane:
        // bytes [0,1,1,2, 1,2,2,3, 1,2,2,3, 2,3,3,4].
        let lo_q = 0x0302_0201_0201_0100u64 as i64;
        let hi_q = 0x0403_0302_0302_0201u64 as i64;
        let lut = _mm256_set_epi64x(hi_q, lo_q, hi_q, lo_q);
        let low = _mm256_set1_epi8(0x0f);
        let lo = _mm256_and_si256(v, low);
        let hi = _mm256_and_si256(_mm256_srli_epi16::<4>(v), low);
        _mm256_add_epi8(_mm256_shuffle_epi8(lut, lo), _mm256_shuffle_epi8(lut, hi))
    }

    /// Carry-save full adder of `(*l, a, b)`: the sum bit stays in `l`,
    /// the carry bit overwrites `h`.
    ///
    /// # Safety
    /// Requires AVX2.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn csa(h: &mut __m256i, l: &mut __m256i, a: __m256i, b: __m256i) {
        let u = _mm256_xor_si256(*l, a);
        *h = _mm256_or_si256(_mm256_and_si256(*l, a), _mm256_and_si256(u, b));
        *l = _mm256_xor_si256(u, b);
    }

    /// Sum of the four u64 lanes.
    ///
    /// # Safety
    /// Requires AVX2.
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi64(v: __m256i) -> u64 {
        let s = _mm_add_epi64(_mm256_castsi256_si128(v), _mm256_extracti128_si256::<1>(v));
        let s = _mm_add_epi64(s, _mm_unpackhi_epi64(s, s));
        _mm_cvtsi128_si64(s) as u64
    }

    /// AND+popcount over word strips: Harley–Seal carry-save
    /// accumulation over 16-word (4-vector) blocks, so only the
    /// weight-4 partial is popcounted per block; the weight-1/2
    /// residues are popcounted once at the end and `vpsadbw` folds byte
    /// counts into u64 lanes. Whole-vector then word-wise tails.
    ///
    /// # Safety
    /// Requires AVX2 at runtime; `a` and `b` must be equal-length.
    #[target_feature(enable = "avx2")]
    pub unsafe fn popcount_and_avx2(a: &[u64], b: &[u64]) -> u64 {
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let zero = _mm256_setzero_si256();
        let mut sad = zero;
        let mut ones = zero;
        let mut twos = zero;
        let mut i = 0usize;
        while i + 16 <= n {
            let mut twos_a = zero;
            let mut twos_b = zero;
            let mut fours = zero;
            csa(&mut twos_a, &mut ones, and_load(ap, bp, i), and_load(ap, bp, i + 4));
            csa(&mut twos_b, &mut ones, and_load(ap, bp, i + 8), and_load(ap, bp, i + 12));
            csa(&mut fours, &mut twos, twos_a, twos_b);
            sad = _mm256_add_epi64(sad, _mm256_sad_epu8(popcnt_bytes(fours), zero));
            i += 16;
        }
        let mut total = 4 * hsum_epi64(sad)
            + 2 * hsum_epi64(_mm256_sad_epu8(popcnt_bytes(twos), zero))
            + hsum_epi64(_mm256_sad_epu8(popcnt_bytes(ones), zero));
        let mut tail = zero;
        while i + 4 <= n {
            let v = popcnt_bytes(and_load(ap, bp, i));
            tail = _mm256_add_epi64(tail, _mm256_sad_epu8(v, zero));
            i += 4;
        }
        total += hsum_epi64(tail);
        while i < n {
            total += (*ap.add(i) & *bp.add(i)).count_ones() as u64;
            i += 1;
        }
        total
    }

    /// AND+popcount over word strips with the AVX-512 `vpopcntq`
    /// instruction: 8 words per step, per-qword popcount, one reduce at
    /// the end.
    ///
    /// # Safety
    /// Requires AVX-512F and AVX-512VPOPCNTDQ at runtime; `a` and `b`
    /// must be equal-length.
    #[target_feature(enable = "avx512f,avx512vpopcntdq")]
    pub unsafe fn popcount_and_avx512(a: &[u64], b: &[u64]) -> u64 {
        let n = a.len();
        let ap = a.as_ptr() as *const i64;
        let bp = b.as_ptr() as *const i64;
        let mut acc = _mm512_setzero_si512();
        let mut i = 0usize;
        while i + 8 <= n {
            let l = _mm512_loadu_epi64(ap.add(i));
            let r = _mm512_loadu_epi64(bp.add(i));
            acc = _mm512_add_epi64(acc, _mm512_popcnt_epi64(_mm512_and_si512(l, r)));
            i += 8;
        }
        let mut total = _mm512_reduce_add_epi64(acc) as u64;
        while i < n {
            total += (a[i] & b[i]).count_ones() as u64;
            i += 1;
        }
        total
    }

    /// AVX2 chunk packer: for each plane `p`, shift bit `p` of four
    /// lanes up to the sign bit (`vpsllq` with a runtime count — the
    /// plane index is not a compile-time constant) and gather the four
    /// sign bits with `vmovmskpd`, building each plane word four
    /// columns at a time. Range checking is vectorized alongside with
    /// signed 64-bit compares; any violation reports `false` and the
    /// caller re-walks the chunk scalarly for its exact panic message.
    ///
    /// # Safety
    /// Requires AVX2 at runtime; `vals.len() <= 64` and
    /// `1 <= words.len() <= 32`.
    #[target_feature(enable = "avx2")]
    pub unsafe fn pack_chunk_avx2(vals: &[i64], lo: i64, hi: i64, words: &mut [u64]) -> bool {
        for w in words.iter_mut() {
            *w = 0;
        }
        let vlo = _mm256_set1_epi64x(lo);
        let vhi = _mm256_set1_epi64x(hi);
        let mut bad = _mm256_setzero_si256();
        let vp = vals.as_ptr();
        let n = vals.len();
        let mut i = 0usize;
        while i + 4 <= n {
            let v = _mm256_loadu_si256(vp.add(i) as *const __m256i);
            bad = _mm256_or_si256(bad, _mm256_cmpgt_epi64(vlo, v));
            bad = _mm256_or_si256(bad, _mm256_cmpgt_epi64(v, vhi));
            for (p, w) in words.iter_mut().enumerate() {
                let sh = _mm256_sll_epi64(v, _mm_cvtsi32_si128(63 - p as i32));
                let nib = _mm256_movemask_pd(_mm256_castsi256_pd(sh)) as u64;
                *w |= nib << i;
            }
            i += 4;
        }
        if _mm256_testz_si256(bad, bad) == 0 {
            return false;
        }
        // Word-wise tail, identical to the scalar packer.
        let mask = ((1u128 << words.len()) - 1) as u64;
        while i < n {
            let v = *vp.add(i);
            if v < lo || v > hi {
                return false;
            }
            let mut p = (v as u64) & mask;
            while p != 0 {
                words[p.trailing_zeros() as usize] |= 1u64 << i;
                p &= p - 1;
            }
            i += 1;
        }
        true
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    /// AND+popcount over word strips: `cnt` byte popcount + the
    /// widening pairwise-add chain, two words per step.
    ///
    /// # Safety
    /// Requires NEON at runtime (baseline on AArch64); `a` and `b` must
    /// be equal-length.
    #[target_feature(enable = "neon")]
    pub unsafe fn popcount_and_neon(a: &[u64], b: &[u64]) -> u64 {
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc = vdupq_n_u64(0);
        let mut i = 0usize;
        while i + 2 <= n {
            let v = vandq_u64(vld1q_u64(ap.add(i)), vld1q_u64(bp.add(i)));
            let c = vcntq_u8(vreinterpretq_u8_u64(v));
            acc = vaddq_u64(acc, vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(c))));
            i += 2;
        }
        let mut total = vaddvq_u64(acc);
        while i < n {
            total += (a[i] & b[i]).count_ones() as u64;
            i += 1;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{property_sweep, Rng};

    fn naive(a: &[u64], b: &[u64]) -> u64 {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| (x & y).count_ones() as u64)
            .sum()
    }

    fn range_of(bits: u32, signed: bool) -> (i64, i64) {
        if signed {
            (-(1i64 << (bits - 1)), (1i64 << (bits - 1)) - 1)
        } else {
            (0, (1i64 << bits) - 1)
        }
    }

    #[test]
    fn every_supported_tier_matches_the_naive_strip() {
        let tiers = DispatchTier::supported();
        assert!(tiers.contains(&DispatchTier::Scalar));
        property_sweep(0x51D0, 40, |rng, _| {
            // Lengths straddling every vector boundary: empty, below
            // the widest vector (8 words), around the 16-word
            // Harley–Seal block, and odd tails beyond it.
            let len = *rng.pick(&[0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 47, 100]);
            let a: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let want = naive(&a, &b);
            for &t in &tiers {
                assert_eq!(popcount_and_tier(t, &a, &b), want, "tier={t} len={len}");
            }
        });
    }

    #[test]
    fn strip_extremes_on_every_tier() {
        for &t in &DispatchTier::supported() {
            assert_eq!(popcount_and_tier(t, &[], &[]), 0, "tier={t}");
            for len in [1usize, 3, 4, 15, 16, 17, 33] {
                let ones = vec![u64::MAX; len];
                let zero = vec![0u64; len];
                assert_eq!(popcount_and_tier(t, &ones, &ones), 64 * len as u64, "tier={t}");
                assert_eq!(popcount_and_tier(t, &ones, &zero), 0, "tier={t}");
            }
        }
    }

    #[test]
    fn pack_chunk_is_word_identical_across_tiers() {
        property_sweep(0x9ACC, 60, |rng, _| {
            let bits = rng.index(8) as u32 + 1;
            let signed = rng.chance(0.5);
            let (lo, hi) = range_of(bits, signed);
            // Chunk lengths cover empty, sub-vector, vector-aligned and
            // the full 64-column word.
            let n = *rng.pick(&[0usize, 1, 3, 4, 5, 8, 17, 31, 32, 63, 64]);
            let vals: Vec<i64> = (0..n).map(|_| rng.operand(bits, signed)).collect();
            let mut want = vec![0u64; bits as usize];
            assert!(pack_chunk_scalar(&vals, lo, hi, &mut want));
            for &t in &DispatchTier::supported() {
                // Poisoned output buffer: the packer must overwrite it.
                let mut got = vec![0xDEAD_BEEF_DEAD_BEEFu64; bits as usize];
                assert!(pack_chunk(t, &vals, lo, hi, &mut got));
                assert_eq!(got, want, "tier={t} bits={bits} signed={signed} n={n}");
            }
        });
    }

    #[test]
    fn pack_chunk_rejects_out_of_range_on_every_tier() {
        for bits in [1u32, 4, 8] {
            for signed in [false, true] {
                let (lo, hi) = range_of(bits, signed);
                // Bad value both inside the vector body and in the tail.
                for pos in [0usize, 2, 5, 62] {
                    let mut vals = vec![0i64; 63];
                    vals[pos] = hi + 1;
                    for &t in &DispatchTier::supported() {
                        let mut words = vec![0u64; bits as usize];
                        assert!(
                            !pack_chunk(t, &vals, lo, hi, &mut words),
                            "tier={t} bits={bits} signed={signed} pos={pos}"
                        );
                    }
                    if signed {
                        vals[pos] = lo - 1;
                        for &t in &DispatchTier::supported() {
                            let mut words = vec![0u64; bits as usize];
                            assert!(!pack_chunk(t, &vals, lo, hi, &mut words));
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn parse_override_accepts_known_names_and_rejects_garbage() {
        assert_eq!(DispatchTier::parse_override("auto").unwrap(), None);
        assert_eq!(DispatchTier::parse_override("").unwrap(), None);
        let scalar = DispatchTier::parse_override(" Scalar ").unwrap();
        assert_eq!(scalar, Some(DispatchTier::Scalar));
        assert_eq!(DispatchTier::parse_override("AVX2").unwrap(), Some(DispatchTier::Avx2));
        let a512 = DispatchTier::parse_override("avx512").unwrap();
        assert_eq!(a512, Some(DispatchTier::Avx512));
        assert_eq!(DispatchTier::parse_override("neon").unwrap(), Some(DispatchTier::Neon));
        for garbage in ["sse9", "AVX-512", "fast", "scalar,avx2"] {
            let err = DispatchTier::parse_override(garbage).unwrap_err();
            assert!(matches!(err, BismoError::InvalidConfig(_)), "{garbage}: {err}");
            assert!(err.to_string().contains(ENV_VAR), "{garbage}: {err}");
        }
    }

    #[test]
    fn detect_and_active_are_supported_and_consistent() {
        let detected = DispatchTier::detect();
        assert!(detected.is_available());
        assert!(DispatchTier::supported().contains(&detected));
        // Under both CI jobs (BISMO_SIMD unset/auto and =scalar) the
        // cached process-wide tier equals what resolve() derives.
        let active = DispatchTier::active();
        assert_eq!(active, DispatchTier::resolve().unwrap());
        assert!(active.is_available());
        match DispatchTier::from_env().unwrap() {
            Some(forced) => assert_eq!(active, forced),
            None => assert_eq!(active, detected),
        }
    }

    #[test]
    fn tier_names_round_trip_through_parse() {
        for t in [
            DispatchTier::Scalar,
            DispatchTier::Neon,
            DispatchTier::Avx2,
            DispatchTier::Avx512,
        ] {
            assert_eq!(DispatchTier::parse_override(t.name()).unwrap(), Some(t));
            assert_eq!(format!("{t}"), t.name());
        }
    }
}
