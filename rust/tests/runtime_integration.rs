//! Integration: AOT artifacts load, compile and execute through PJRT,
//! and their numerics agree bit-exactly with the Rust oracles and the
//! overlay simulator. Requires `make artifacts` (skips cleanly if the
//! artifact directory has not been built) and the `xla` cargo feature
//! (the whole file is compiled out without it).

#![cfg(feature = "xla")]

use bismo::arch::BismoConfig;
use bismo::bitmatrix::{BitSerialMatrix, IntMatrix};
use bismo::coordinator::{BismoContext, MatmulOptions, Precision};
use bismo::qnn::{FloatMlp, QnnMlp, SyntheticDigits};
use bismo::runtime::Runtime;
use bismo::util::Rng;
use std::path::{Path, PathBuf};

fn artifact_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn matmul_artifact_matches_reference_and_overlay() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let exe = rt.load("bitserial_matmul_64x256x64_w4a4_ss").expect("load");

    let mut rng = Rng::new(0xA0);
    let a = IntMatrix::random(&mut rng, 64, 256, 4, true);
    let b = IntMatrix::random(&mut rng, 256, 64, 4, true);
    let want = a.matmul(&b);

    // PJRT path (JAX/Pallas artifact).
    let got = exe.run_i32(&[&a, &b]).expect("execute");
    assert_eq!(got, want, "PJRT artifact vs i64 reference");

    // Overlay simulator path.
    let ctx = BismoContext::new(BismoConfig::small()).unwrap();
    let (sim_out, _) = ctx
        .matmul(&a, &b, Precision::signed(4, 4), MatmulOptions::default())
        .unwrap();
    assert_eq!(sim_out, want, "overlay simulator vs i64 reference");
}

#[test]
fn matmul_artifact_caches_compilation() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let e1 = rt.load("bitserial_matmul_8x2048x8_w2a2_uu").expect("load");
    let e2 = rt.load("bitserial_matmul_8x2048x8_w2a2_uu").expect("load");
    assert!(std::sync::Arc::ptr_eq(&e1, &e2), "cache must hit");
}

#[test]
fn popcount_artifact_matches_bitserial_oracle() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let exe = rt.load("binary_matmul_popcount_64x2048x64").expect("load");

    let mut rng = Rng::new(0xA1);
    let (m, k, n) = (64usize, 2048usize, 64usize);
    let a = IntMatrix::random(&mut rng, m, k, 1, false);
    let b = IntMatrix::random(&mut rng, k, n, 1, false);

    // Pack planes into u32 words, little-endian bit order (the
    // kernel-side convention of ref.pack_bits_u32).
    let pack = |mat: &IntMatrix| -> Vec<u32> {
        let kw = k / 32;
        let mut out = vec![0u32; mat.rows * kw];
        for r in 0..mat.rows {
            for c in 0..k {
                if mat.get(r, c) == 1 {
                    out[r * kw + c / 32] |= 1 << (c % 32);
                }
            }
        }
        out
    };
    let la = pack(&a);
    let rb = pack(&b.transpose());
    let got = exe
        .run_u32_pair((&la, [m, k / 32]), (&rb, [n, k / 32]))
        .expect("execute");
    assert_eq!(got, a.matmul(&b), "popcount artifact vs reference");

    // Also check against the u64-word CPU DPU oracle.
    let la64 = BitSerialMatrix::from_int(&a, 1, false);
    let rb64 = BitSerialMatrix::from_int(&b.transpose(), 1, false);
    assert_eq!(bismo::baseline::gemm_bitserial(&la64, &rb64), got);
}

#[test]
fn qnn_artifact_matches_rust_quantized_model() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let exe = rt.load("qnn_mlp_b16_w4a2").expect("load");

    // Train + quantize the same way the E2E example does.
    let d = SyntheticDigits::generate(42, 200, 16, 0.15);
    let mut mlp = FloatMlp::new(7, [784, 256, 256, 10]);
    mlp.train_epoch(&d.train_x, &d.train_y, 0.02, 0);
    let q = QnnMlp::from_float(&mlp, 4, 2, (6, 4));

    let x = q.quantize_input(&d.test_x[..16]);
    let want = q.forward_reference(&x);

    let inputs: [&bismo::bitmatrix::IntMatrix; 4] = [&x, &q.w1, &q.w2, &q.w3];
    let got = exe.run_i32(&inputs).expect("execute");
    assert_eq!(got, want, "JAX QNN artifact vs Rust integer reference");

    // And the full overlay path agrees too.
    let ctx = BismoContext::new(BismoConfig::small()).unwrap();
    let (overlay_logits, _) = q
        .forward_on_overlay(&ctx, &x, MatmulOptions::default())
        .unwrap();
    assert_eq!(overlay_logits, want, "overlay QNN vs artifact");
}

#[test]
fn missing_artifact_is_clean_error() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let err = match rt.load("does_not_exist") {
        Ok(_) => panic!("load of unknown artifact must fail"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("not in manifest"), "{err}");
}

#[test]
fn wrong_shape_is_clean_error() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let exe = rt.load("bitserial_matmul_64x256x64_w4a4_ss").expect("load");
    let a = IntMatrix::zeros(8, 8);
    let b = IntMatrix::zeros(8, 8);
    let err = exe.run_i32(&[&a, &b]).unwrap_err().to_string();
    assert!(err.contains("shape"), "{err}");
}
