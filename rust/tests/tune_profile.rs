//! Tuned-profile integration: persisted profiles round-trip through
//! their content-addressed files, corrupt or mis-addressed profiles
//! are rejected with typed errors (and degrade to the analytical
//! defaults on the implicit startup path), and sessions running under
//! arbitrary legal tuned tile picks stay bit-exact against the oracle
//! on both backends and every supported SIMD tier.

use bismo::api::{Backend, BismoError, KernelConfig, Session, SessionConfig, TunedProfile};
use bismo::bitmatrix::{BitSerialMatrix, IntMatrix};
use bismo::coordinator::Precision;
use bismo::costmodel::tune::{load_host_profile_in, SHAPE_CLASSES};
use bismo::costmodel::{ClassTuning, CostModel, CpuFingerprint, SwFit};
use bismo::kernel::gemm_tiled_block_tier;
use bismo::simd::DispatchTier;
use bismo::util::{property_sweep, Rng};
use std::path::PathBuf;

/// A scratch directory unique to this test run (the tests never touch
/// the process environment, so `BISMO_TUNE_DIR` races cannot occur).
fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bismo_tune_test_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn random_tile(rng: &mut Rng) -> KernelConfig {
    KernelConfig {
        tile_m: rng.index(32) + 1,
        tile_n: rng.index(32) + 1,
        tile_k: *rng.pick(&[usize::MAX, 1, 37, 64, 128, 1000]),
    }
}

/// A profile whose every shape class carries an arbitrary (legal)
/// tile pick — the shape a real `bismo tune` run would persist.
fn profile_with_tiles(fp: CpuFingerprint, rng: &mut Rng) -> TunedProfile {
    let classes = SHAPE_CLASSES
        .iter()
        .map(|&class| ClassTuning {
            class,
            tile: random_tile(rng),
            shards: rng.index(4) + 1,
            grid: (rng.index(2) + 1, rng.index(2) + 1),
            measured_gops: 2.0,
            default_gops: 1.0,
        })
        .collect();
    TunedProfile {
        fingerprint: fp,
        cost_model: CostModel::paper(),
        sw_fit: SwFit {
            ns_per_op: 0.01,
            ns_base: 50.0,
        },
        classes,
        generated_unix: 0,
    }
}

fn host_fp() -> CpuFingerprint {
    CpuFingerprint::detect().unwrap()
}

#[test]
fn sessions_under_arbitrary_tuned_tiles_stay_bit_exact() {
    property_sweep(0x7E57_70E, 8, |rng, case| {
        let profile = profile_with_tiles(host_fp(), rng);
        let session = Session::with_profile(SessionConfig::default(), Some(profile)).unwrap();
        let m = rng.index(10) + 1;
        let k = rng.index(128) + 1;
        let n = rng.index(10) + 1;
        let prec = Precision {
            wbits: rng.index(3) as u32 + 1,
            abits: rng.index(3) as u32 + 1,
            lsigned: rng.chance(0.5),
            rsigned: rng.chance(0.5),
        };
        let a = IntMatrix::random(rng, m, k, prec.wbits, prec.lsigned);
        let b = IntMatrix::random(rng, k, n, prec.abits, prec.rsigned);
        let expect = a.matmul(&b);
        for backend in [Backend::Engine, Backend::Sim] {
            let resp = session
                .matmul(prec)
                .backend(backend)
                .run(a.clone(), b.clone())
                .unwrap();
            assert_eq!(resp.result, expect, "case {case}: {}", backend.name());
        }
        // An explicit builder tile overrides the profile pick and must
        // be just as exact.
        let resp = session
            .matmul(prec)
            .tile(random_tile(rng))
            .run(a.clone(), b.clone())
            .unwrap();
        assert_eq!(resp.result, expect, "case {case}: explicit tile");
    });
}

#[test]
fn block_paths_match_oracle_under_arbitrary_tiles_on_every_tier() {
    // The raw engine half of the property: any legal tile geometry
    // (k-chunking included), any supported forced tier, full-output
    // block — bit-exact against the i64 reference.
    let tiers = DispatchTier::supported();
    property_sweep(0x7E57_B10C, 12, |rng, case| {
        let m = rng.index(20) + 1;
        let k = rng.index(300) + 1;
        let n = rng.index(20) + 1;
        let wbits = rng.index(6) as u32 + 1;
        let abits = rng.index(6) as u32 + 1;
        let a = IntMatrix::random(rng, m, k, wbits, true);
        let b = IntMatrix::random(rng, k, n, abits, false);
        let expect = a.matmul(&b);
        let rb = BitSerialMatrix::from_int_transposed(&b, abits, false);
        let cfg = random_tile(rng);
        for &tier in &tiers {
            let la = BitSerialMatrix::from_int_tier(&a, wbits, true, tier);
            let got = gemm_tiled_block_tier(&la, &rb, 0..m, 0..n, None, &cfg, None, tier).unwrap();
            assert_eq!(
                got, expect,
                "case {case}: tier={tier} tile {}x{}x{}",
                cfg.tile_m, cfg.tile_n, cfg.tile_k
            );
        }
    });
}

#[test]
fn profile_roundtrips_through_its_content_addressed_file() {
    let dir = scratch_dir("roundtrip");
    let mut rng = Rng::new(0x0F11E);
    let profile = profile_with_tiles(host_fp(), &mut rng);
    let path = profile.save_in(&dir).unwrap();
    assert!(path.ends_with(format!("bismo-tune-{}.json", profile.key())));
    let loaded = TunedProfile::load_for(&dir, &profile.fingerprint)
        .unwrap()
        .expect("profile present");
    assert_eq!(loaded, profile);
    // The implicit startup loader finds it too.
    assert_eq!(load_host_profile_in(&dir), Some(profile));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_profile_is_a_typed_parse_error_and_startup_falls_back() {
    let dir = scratch_dir("corrupt");
    let fp = host_fp();
    let path = dir.join(format!("bismo-tune-{}.json", fp.key()));
    std::fs::write(&path, "{\"schema\": \"bismo-tune-profile/v1\", \"oops").unwrap();
    match TunedProfile::load_for(&dir, &fp) {
        Err(BismoError::Parse(_)) => {}
        other => panic!("expected a typed Parse error, got {other:?}"),
    }
    // The session startup path swallows the error: analytical defaults,
    // fully working service.
    assert_eq!(load_host_profile_in(&dir), None);
    let session = Session::with_profile(SessionConfig::default(), load_host_profile_in(&dir)).unwrap();
    assert!(session.tuned_profile().is_none());
    let a = IntMatrix::from_slice(2, 2, &[2, 0, 1, 3]);
    let b = IntMatrix::from_slice(2, 2, &[0, 1, 1, 2]);
    let expect = a.matmul(&b);
    let resp = session.run(a, b, Precision::unsigned(2, 2)).unwrap();
    assert_eq!(resp.result, expect);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn profile_copied_between_machines_is_rejected() {
    // A profile whose *content* names another machine, sitting at this
    // host's content address (somebody copied a profile file across
    // machines): typed Parse rejection, None from the startup loader.
    let dir = scratch_dir("mismatch");
    let host = host_fp();
    let other = CpuFingerprint {
        simd_tier: "imaginary-tier".to_string(),
        cores: host.cores + 7,
    };
    let mut rng = Rng::new(0xC0_7F);
    let foreign = profile_with_tiles(other, &mut rng);
    let path = dir.join(format!("bismo-tune-{}.json", host.key()));
    std::fs::write(&path, foreign.to_json().pretty(2) + "\n").unwrap();
    match TunedProfile::load_for(&dir, &host) {
        Err(BismoError::Parse(msg)) => {
            assert!(msg.contains("fingerprint mismatch"), "{msg}");
        }
        other => panic!("expected a typed Parse error, got {other:?}"),
    }
    assert_eq!(load_host_profile_in(&dir), None);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn missing_profile_dir_degrades_to_analytical_defaults() {
    let dir = std::env::temp_dir().join(format!(
        "bismo_tune_test_absent_{}_never_created",
        std::process::id()
    ));
    assert_eq!(load_host_profile_in(&dir), None);
    let session = Session::with_profile(SessionConfig::default(), None).unwrap();
    assert!(session.tuned_profile().is_none());
}

#[test]
fn degenerate_builder_tile_is_rejected_before_queueing() {
    let session = Session::with_profile(SessionConfig::default(), None).unwrap();
    let a = IntMatrix::from_slice(2, 2, &[1, 0, 0, 1]);
    let b = IntMatrix::from_slice(2, 2, &[1, 2, 3, 4]);
    for bad in [
        KernelConfig {
            tile_m: 0,
            ..KernelConfig::default()
        },
        KernelConfig {
            tile_n: 0,
            ..KernelConfig::default()
        },
        KernelConfig {
            tile_k: 0,
            ..KernelConfig::default()
        },
    ] {
        let err = session
            .matmul(Precision::unsigned(2, 2))
            .tile(bad)
            .submit(a.clone(), b.clone())
            .expect_err("degenerate tile must be rejected");
        assert!(
            matches!(err, BismoError::InvalidConfig(_)),
            "expected InvalidConfig, got {err:?}"
        );
    }
}

#[test]
fn zero_tile_k_parses_back_as_whole_k() {
    // The disk convention: `tile_k = 0` in the JSON is the unchunked
    // sentinel (`usize::MAX`) in memory, so a persisted default tile
    // round-trips to the default.
    let dir = scratch_dir("tilek");
    let mut rng = Rng::new(0x71E_0);
    let mut profile = profile_with_tiles(host_fp(), &mut rng);
    for c in &mut profile.classes {
        c.tile = KernelConfig::default();
    }
    profile.save_in(&dir).unwrap();
    let loaded = TunedProfile::load_for(&dir, &profile.fingerprint)
        .unwrap()
        .unwrap();
    for c in &loaded.classes {
        assert_eq!(c.tile, KernelConfig::default());
        assert_eq!(c.tile.tile_k, usize::MAX);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
