//! Builder-parity matrix: the matmul, conv and attention builders
//! expose the *same* [`ExecOpts`] knob surface (stamped on by one
//! macro), validate it at `build()` with *identical* typed errors
//! before anything is queued, and share the prepare-once contract —
//! including the identical rejection of `cache_rhs(false)` + prepare.

use bismo::api::{
    Backend, BismoError, ConvSpec, ExecOpts, KernelConfig, LoweringMode, OpHandle, Overlap,
    PreparedOp, Precision, ResourceBudget, Session, Tensor,
};
use bismo::bitmatrix::IntMatrix;
use bismo::lowering::conv2d_direct;
use bismo::qnn::{AttnSpec, AttnWeightBits, QnnAttn};
use bismo::util::Rng;

fn session() -> Session {
    Session::with_defaults().unwrap()
}

fn conv_spec() -> ConvSpec {
    ConvSpec::simple(6, 6, 2, 3, 3, 1)
}

fn conv_prec() -> Precision {
    Precision {
        wbits: 2,
        abits: 3,
        lsigned: false,
        rsigned: true,
    }
}

fn attn_model() -> QnnAttn {
    QnnAttn::random(
        5,
        AttnSpec {
            d_model: 8,
            heads: 2,
            d_ff: 12,
            max_seq: 4,
        },
        2,
        AttnWeightBits {
            proj: 2,
            out: 2,
            ffn1: 2,
            ffn2: 2,
        },
    )
}

fn budget() -> ResourceBudget {
    ResourceBudget {
        luts: 100_000,
        brams: 300,
    }
}

fn tile() -> KernelConfig {
    KernelConfig {
        tile_m: 4,
        tile_n: 4,
        tile_k: 64,
    }
}

/// Error message of a failed result — the parity assertions compare
/// these strings across builders, so "identical typed error" means
/// identical down to the rendered text.
fn msg<T>(r: Result<T, BismoError>) -> String {
    match r {
        Err(e) => format!("{e}"),
        Ok(_) => panic!("expected an error"),
    }
}

#[test]
fn every_knob_is_accepted_by_all_three_builders() {
    let s = session();
    // The full knob surface on each builder. The sharding knobs
    // (instances / shard_grid / auto_shard) all set the same option,
    // so chaining them is legal (last one wins); everything must pass
    // build-time validation.
    s.matmul(Precision::unsigned(2, 2))
        .backend(Backend::Sim)
        .overlap(Overlap::None)
        .bit_skip(true)
        .verify(true)
        .max_instrs(1_000_000)
        .cache_lhs(true)
        .cache_rhs(true)
        .cache_namespace(3)
        .instances(2)
        .shard_grid(2, 2)
        .auto_shard(budget())
        .tile(tile())
        .build()
        .unwrap();
    // ConvBuilder historically shipped without max_instrs / overlap /
    // shard_grid / auto_shard / tile — the parity the shared core
    // restores.
    s.conv(conv_spec(), conv_prec())
        .lowering(LoweringMode::Kn2row)
        .backend(Backend::Sim)
        .overlap(Overlap::None)
        .bit_skip(true)
        .verify(true)
        .max_instrs(1_000_000)
        .cache_lhs(true)
        .cache_rhs(true)
        .cache_namespace(3)
        .instances(2)
        .shard_grid(2, 2)
        .auto_shard(budget())
        .tile(tile())
        .build()
        .unwrap();
    s.attn(&attn_model())
        .backend(Backend::Sim)
        .overlap(Overlap::None)
        .bit_skip(true)
        .verify(true)
        .max_instrs(1_000_000)
        .cache_lhs(true)
        .cache_rhs(true)
        .cache_namespace(3)
        .instances(2)
        .shard_grid(2, 2)
        .auto_shard(budget())
        .tile(tile())
        .build()
        .unwrap();
    // A standalone ExecOpts value validates through the same path.
    assert!(ExecOpts::new().shard_grid(2, 2).tile(tile()).validate().is_ok());
}

#[test]
fn degenerate_knobs_fail_identically_and_queue_nothing() {
    let s = session();
    let model = attn_model();
    let submitted = s.service().submitted();

    // instances(0)
    let m = msg(s.matmul(Precision::unsigned(2, 2)).instances(0).build());
    let c = msg(s.conv(conv_spec(), conv_prec()).instances(0).build());
    let a = msg(s.attn(&model).instances(0).build());
    assert_eq!(m, c, "matmul vs conv: instances(0)");
    assert_eq!(m, a, "matmul vs attn: instances(0)");
    assert!(
        matches!(
            s.matmul(Precision::unsigned(2, 2)).instances(0).build(),
            Err(BismoError::InvalidConfig(_))
        ),
        "typed as InvalidConfig"
    );

    // shard_grid with a zero axis
    let m = msg(s.matmul(Precision::unsigned(2, 2)).shard_grid(2, 0).build());
    let c = msg(s.conv(conv_spec(), conv_prec()).shard_grid(2, 0).build());
    let a = msg(s.attn(&model).shard_grid(2, 0).build());
    assert_eq!(m, c, "matmul vs conv: shard_grid(2, 0)");
    assert_eq!(m, a, "matmul vs attn: shard_grid(2, 0)");

    // degenerate pinned tile
    let zero_tile = KernelConfig {
        tile_m: 0,
        tile_n: 1,
        tile_k: 1,
    };
    let m = msg(s.matmul(Precision::unsigned(2, 2)).tile(zero_tile).build());
    let c = msg(s.conv(conv_spec(), conv_prec()).tile(zero_tile).build());
    let a = msg(s.attn(&model).tile(zero_tile).build());
    assert_eq!(m, c, "matmul vs conv: zero tile");
    assert_eq!(m, a, "matmul vs attn: zero tile");
    assert!(
        matches!(
            s.attn(&model).tile(zero_tile).build(),
            Err(BismoError::InvalidConfig(_))
        ),
        "typed as InvalidConfig"
    );

    // Degenerate precision is PrecisionUnsupported on every path (the
    // attention builder validates the model's per-GEMM precisions).
    let bad = Precision {
        wbits: 0,
        abits: 2,
        lsigned: false,
        rsigned: false,
    };
    assert!(matches!(
        s.matmul(bad).build(),
        Err(BismoError::PrecisionUnsupported(_))
    ));
    assert!(matches!(
        s.conv(conv_spec(), bad).build(),
        Err(BismoError::PrecisionUnsupported(_))
    ));
    let mut bad_model = attn_model();
    bad_model.proj_prec.wbits = 0;
    assert!(matches!(
        s.attn(&bad_model).build(),
        Err(BismoError::PrecisionUnsupported(_))
    ));

    // build() rejected everything above before queueing: the serving
    // layer never saw a request. The failing submit/prepare paths are
    // equally pre-queue.
    let r = s
        .matmul(Precision::unsigned(2, 2))
        .instances(0)
        .submit(IntMatrix::zeros(2, 2), IntMatrix::zeros(2, 2));
    assert!(r.is_err());
    let w = conv_spec().weights_from_fn(|_, _, _, _| 0);
    let r = s
        .conv(conv_spec(), conv_prec())
        .instances(0)
        .run(&Tensor::zeros(1, 6, 6, 2), w);
    assert!(r.is_err());
    let r = s.attn(&model).instances(0).prepare();
    assert!(r.is_err());
    assert_eq!(s.service().submitted(), submitted, "nothing was queued");
}

#[test]
fn prepare_with_cache_rhs_off_is_rejected_identically() {
    let s = session();
    let m = msg(
        s.matmul(Precision::unsigned(2, 2))
            .cache_rhs(false)
            .prepare(IntMatrix::zeros(2, 2)),
    );
    let w = conv_spec().weights_from_fn(|_, _, _, _| 0);
    let c = msg(s.conv(conv_spec(), conv_prec()).cache_rhs(false).prepare(w));
    let a = msg(s.attn(&attn_model()).cache_rhs(false).prepare());
    assert_eq!(m, c, "matmul vs conv: prepare without weight caching");
    assert_eq!(m, a, "matmul vs attn: prepare without weight caching");
    assert!(m.contains("cache_rhs(false)"), "{m}");
}

#[test]
fn conv_honors_the_restored_knobs_end_to_end() {
    let s = session();
    let mut rng = Rng::new(0xB17);
    let spec = conv_spec();
    let x = Tensor::random(&mut rng, 1, 6, 6, 2, 2, false);
    let w = spec.weights_from_fn(|_, _, _, _| rng.operand(3, true));
    let want = conv2d_direct(&x, &w, &spec);

    // A pinned engine tile and an (ample) instruction budget are
    // accepted and bit-exact.
    let resp = s
        .conv(spec, conv_prec())
        .tile(tile())
        .max_instrs(50_000_000)
        .verify(true)
        .run(&x, w.clone())
        .unwrap();
    assert_eq!(resp.output, want);

    // An absurdly small sim budget trips the typed watchdog instead of
    // hanging a worker.
    let r = s
        .conv(spec, conv_prec())
        .backend(Backend::Sim)
        .max_instrs(1)
        .run(&x, w.clone());
    assert!(matches!(r, Err(BismoError::SimFault(_))), "{r:?}");

    // An explicit shard grid stays exact through the conv path.
    let resp = s
        .conv(spec, conv_prec())
        .shard_grid(2, 1)
        .verify(true)
        .run(&x, w)
        .unwrap();
    assert_eq!(resp.output, want);
    assert_eq!(resp.gemms[0].shards, 2);
}

/// One generic serving loop over any prepared operator: submit one job
/// asynchronously, run one synchronously, then collect the async
/// result — exactly the [`PreparedOp`] contract.
fn roundtrip<P: PreparedOp>(op: &P, x: &P::Input) -> (P::Output, P::Output) {
    let in_flight = op.submit(x).unwrap();
    let sync = op.execute(x).unwrap();
    (in_flight.wait().unwrap(), sync)
}

#[test]
fn prepared_op_is_generic_over_matmul_and_conv() {
    let s = session();
    let mut rng = Rng::new(0xB18);

    // Prepared matmul through the generic contract.
    let w = IntMatrix::random(&mut rng, 48, 5, 3, true);
    let prec = Precision {
        wbits: 2,
        abits: 3,
        lsigned: false,
        rsigned: true,
    };
    let prepared = s.prepare(w.clone(), prec).unwrap();
    assert_eq!(PreparedOp::precision(&prepared), prec);
    let x = IntMatrix::random(&mut rng, 3, 48, 2, false);
    let (async_resp, sync_resp) = roundtrip(&prepared, &x);
    assert_eq!(async_resp.result, x.matmul(&w));
    assert_eq!(sync_resp.result, x.matmul(&w));

    // Prepared conv through the *same* generic function.
    let spec = conv_spec();
    let cw = spec.weights_from_fn(|_, _, _, _| rng.operand(3, true));
    let prepared = s.conv(spec, conv_prec()).prepare(cw.clone()).unwrap();
    let xt = Tensor::random(&mut rng, 1, 6, 6, 2, 2, false);
    let want = conv2d_direct(&xt, &cw, &spec);
    let (async_resp, sync_resp) = roundtrip(&prepared, &xt);
    assert_eq!(async_resp.output, want);
    assert_eq!(sync_resp.output, want);

    // The per-execute precision override is part of the contract too.
    let wider = Precision {
        wbits: 3,
        abits: 4,
        lsigned: false,
        rsigned: true,
    };
    let r = PreparedOp::execute_with(&prepared, &xt, wider).unwrap();
    assert_eq!(r.output, want, "declared headroom changes nothing");
}
