//! Forced-dispatch test matrix for the SIMD datapath: every tier the
//! host supports must be bit-exact with the scalar reference — same
//! packed plane words out of the packers, same GEMM results out of the
//! engine — across precisions, signedness, ragged shapes, vector-width
//! tails, single-word rows and all-zero (skippable) planes. Tier
//! selection itself is covered too: garbage `BISMO_SIMD` values are a
//! typed `InvalidConfig`, never a silent fallback (the process-level
//! env behavior is exercised by the CLI suite and the CI forced-scalar
//! job; here we test the pure parsing layer to stay race-free across
//! test threads).

use bismo::api::BismoError;
use bismo::bitmatrix::{BitSerialMatrix, IntMatrix};
use bismo::kernel::gemm_tiled_tier;
use bismo::simd::{self, DispatchTier};
use bismo::util::{property_sweep, Rng};
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Pack with an explicit tier and check word-identity against the
/// scalar packer — both the dense and the virtual (`from_int_fn`)
/// entry points.
fn assert_packing_matches_scalar(m: &IntMatrix, bits: u32, signed: bool) {
    let want = BitSerialMatrix::from_int_tier(m, bits, signed, DispatchTier::Scalar);
    let want_fn = BitSerialMatrix::from_int_fn_tier(m.rows, m.cols, bits, signed, DispatchTier::Scalar, |r, c| {
        m.get(r, c)
    });
    assert_eq!(want, want_fn, "scalar from_int vs from_int_fn");
    for tier in DispatchTier::supported() {
        let got = BitSerialMatrix::from_int_tier(m, bits, signed, tier);
        assert_eq!(got, want, "from_int tier={tier} bits={bits} signed={signed}");
        let got_fn =
            BitSerialMatrix::from_int_fn_tier(m.rows, m.cols, bits, signed, tier, |r, c| m.get(r, c));
        assert_eq!(got_fn, want, "from_int_fn tier={tier} bits={bits} signed={signed}");
    }
}

#[test]
fn every_supported_tier_is_bit_exact_against_the_oracle() {
    let tiers = DispatchTier::supported();
    assert!(tiers.contains(&DispatchTier::Scalar), "scalar always runs");
    property_sweep(0x51D_0D15, 40, |rng, case| {
        let m = rng.index(17) + 1;
        let k = rng.index(260) + 1; // usually not a multiple of 64 or the vector width
        let n = rng.index(17) + 1;
        let wbits = rng.index(8) as u32 + 1;
        let abits = rng.index(8) as u32 + 1;
        let lsigned = rng.chance(0.5);
        let rsigned = rng.chance(0.5);
        let a = IntMatrix::random(rng, m, k, wbits, lsigned);
        let b = IntMatrix::random(rng, k, n, abits, rsigned);
        let expect = a.matmul(&b);
        let rb = BitSerialMatrix::from_int_transposed(&b, abits, rsigned);
        for &tier in &tiers {
            let la = BitSerialMatrix::from_int_tier(&a, wbits, lsigned, tier);
            assert_eq!(
                gemm_tiled_tier(&la, &rb, tier).unwrap(),
                expect,
                "case {case}: tier={tier} m={m} k={k} n={n} w={wbits} a={abits} \
                 ls={lsigned} rs={rsigned}"
            );
        }
    });
}

#[test]
fn packing_is_word_identical_across_tiers() {
    property_sweep(0x9ACC_ED, 40, |rng, _| {
        let rows = rng.index(9) + 1;
        // Straddle the 64-column word boundary and the 4-column AVX2
        // packer step: tails of every phase.
        let cols = *rng.pick(&[1usize, 3, 4, 5, 31, 63, 64, 65, 100, 128, 130]);
        let bits = rng.index(8) as u32 + 1;
        let signed = rng.chance(0.5);
        let m = IntMatrix::random(rng, rows, cols, bits, signed);
        assert_packing_matches_scalar(&m, bits, signed);
    });
}

#[test]
fn strip_tails_shorter_than_every_vector_width() {
    // k below / at / just past each vector width (NEON 2 words, AVX2 4,
    // AVX-512 8, Harley–Seal block 16) — in *words*, so k in bits spans
    // 1..=17 words. Single-word rows (k <= 64) are the smallest case.
    let mut rng = Rng::new(0x7A11);
    for kwords in [1usize, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17] {
        let k = kwords * 64 - rng.index(5); // ragged: not always word-aligned
        let a = IntMatrix::random(&mut rng, 3, k, 2, true);
        let b = IntMatrix::random(&mut rng, k, 3, 3, false);
        let expect = a.matmul(&b);
        let rb = BitSerialMatrix::from_int_transposed(&b, 3, false);
        for tier in DispatchTier::supported() {
            let la = BitSerialMatrix::from_int_tier(&a, 2, true, tier);
            assert_eq!(
                gemm_tiled_tier(&la, &rb, tier).unwrap(),
                expect,
                "tier={tier} k={k}"
            );
        }
    }
}

#[test]
fn all_zero_and_skippable_planes_agree_on_every_tier() {
    let mut rng = Rng::new(0x5C1F);
    let (m, k, n) = (5, 150, 6);
    // Even values: LSB plane all-zero (zero-plane skip path). All-zero
    // operand: every plane skippable. Dense control alongside.
    let dense = IntMatrix::random(&mut rng, m, k, 4, false);
    let even = IntMatrix::from_fn(m, k, |r, c| (dense.get(r, c) / 2) * 2);
    let zero = IntMatrix::zeros(m, k);
    let b = IntMatrix::random(&mut rng, k, n, 3, true);
    let rb = BitSerialMatrix::from_int_transposed(&b, 3, true);
    for a in [&dense, &even, &zero] {
        let expect = a.matmul(&b);
        assert_packing_matches_scalar(a, 4, false);
        for tier in DispatchTier::supported() {
            let la = BitSerialMatrix::from_int_tier(a, 4, false, tier);
            assert_eq!(gemm_tiled_tier(&la, &rb, tier).unwrap(), expect, "tier={tier}");
        }
    }
}

#[test]
fn packing_panics_carry_the_same_message_on_every_tier() {
    for tier in DispatchTier::supported() {
        let bad = IntMatrix::from_slice(1, 70, &[3; 70]); // 3 does not fit 1 bit
        let err = catch_unwind(AssertUnwindSafe(|| {
            BitSerialMatrix::from_int_tier(&bad, 1, false, tier)
        }))
        .expect_err("out-of-range entry must panic");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("does not fit"), "tier={tier}: {msg}");
        let err = catch_unwind(AssertUnwindSafe(|| {
            BitSerialMatrix::from_int_fn_tier(1, 70, 2, true, tier, |_, c| c as i64)
        }))
        .expect_err("out-of-range produced value must panic");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("does not fit"), "tier={tier}: {msg}");
    }
}

#[test]
fn override_parsing_rejects_garbage_with_a_typed_error() {
    for garbage in ["sse4", "AVX512VNNI", "fastest", "scalar avx2", "1"] {
        let err = DispatchTier::parse_override(garbage).unwrap_err();
        assert!(
            matches!(err, BismoError::InvalidConfig(_)),
            "{garbage}: wrong error class: {err}"
        );
        let text = err.to_string();
        assert!(text.contains(simd::ENV_VAR), "{garbage}: {text}");
        assert!(text.contains("scalar"), "{garbage}: lists valid names: {text}");
    }
    // from_env under the CI matrix: whatever BISMO_SIMD is set to
    // (unset, auto or a forced tier), it must parse and resolve, and
    // the cached process-wide tier must agree.
    let over = DispatchTier::from_env().expect("CI sets only valid BISMO_SIMD values");
    let resolved = DispatchTier::resolve().unwrap();
    match over {
        Some(t) => assert_eq!(resolved, t),
        None => assert_eq!(resolved, DispatchTier::detect()),
    }
    assert_eq!(DispatchTier::active(), resolved);
}
