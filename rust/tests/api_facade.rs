//! Integration tests for the `bismo::api` facade: prepared operands
//! are bit-exact against the CPU bit-serial oracle on BOTH backends,
//! reuse skips repacking (observed through `CacheStats`), and errors
//! are typed end to end.

use bismo::api::{Backend, BismoError, Precision, Session, SessionConfig};
use bismo::arch::BismoConfig;
use bismo::baseline::gemm_bitserial;
use bismo::bitmatrix::{BitSerialMatrix, IntMatrix};
use bismo::util::{property_sweep, Rng};
use std::sync::Arc;

fn session() -> Session {
    Session::new(SessionConfig {
        workers: 2,
        max_batch: 4,
        cache_bytes: 32 << 20,
        overlay: BismoConfig::small(),
    })
    .unwrap()
}

/// Oracle product via the naive bit-serial reference.
fn oracle(a: &IntMatrix, b: &IntMatrix, prec: Precision) -> IntMatrix {
    let la = BitSerialMatrix::from_int(a, prec.wbits, prec.lsigned);
    let rb = BitSerialMatrix::from_int_transposed(b, prec.abits, prec.rsigned);
    gemm_bitserial(&la, &rb)
}

#[test]
fn prepared_weights_are_bit_exact_on_both_backends_and_never_repacked() {
    let s = session();
    let mut rng = Rng::new(0xFACADE);
    // Signed weights with ragged k (not a multiple of 64) and ragged n.
    let prec = Precision {
        wbits: 2, // activations, unsigned
        abits: 4, // weights, signed
        lsigned: false,
        rsigned: true,
    };
    let w = Arc::new(IntMatrix::random(&mut rng, 130, 5, 4, true));

    let engine = s.matmul(prec).backend(Backend::Engine).prepare(w.clone()).unwrap();
    // Same weights, same precision: the sim-backend handle finds the
    // packing already resident.
    let sim = s.matmul(prec).backend(Backend::Sim).prepare(w.clone()).unwrap();
    let after_prepare = s.cache_stats();
    assert_eq!(after_prepare.insertions, 1, "one packing for both handles");

    for i in 0..4 {
        let x = IntMatrix::random(&mut rng, 3, 130, 2, false);
        let expect = oracle(&x, &w, prec);
        assert_eq!(expect, x.matmul(&w), "oracle agrees with i64 reference");
        let re = engine.execute(x.clone()).unwrap();
        let rs = sim.execute(x.clone()).unwrap();
        assert_eq!(re.result, expect, "engine backend, execute {i}");
        assert_eq!(rs.result, expect, "sim backend, execute {i}");
        assert!(re.report.is_none() && rs.report.is_some());
        assert!(re.rhs_cached && rs.rhs_cached, "execute {i} reused the packing");
    }

    // The reuse contract, stated in counters: executes added cache hits
    // but ZERO new misses or insertions — nothing was ever repacked.
    let after = s.cache_stats();
    assert_eq!(after.misses, after_prepare.misses, "no repack misses");
    assert_eq!(
        after.insertions, after_prepare.insertions,
        "no repack insertions"
    );
    assert_eq!(after.hits, after_prepare.hits + 8, "8 executes, 8 hits");
}

#[test]
fn prepared_reuse_property_sweep_signed_and_ragged() {
    let s = session();
    property_sweep(0x9A9ADE, 8, |rng, case| {
        let k = rng.index(190) + 1; // frequently ragged
        let n = rng.index(9) + 1;
        let m = rng.index(5) + 1;
        let wb = rng.index(4) as u32 + 1;
        let ab = rng.index(4) as u32 + 1;
        let (ls, rs) = (rng.chance(0.5), rng.chance(0.5));
        let prec = Precision {
            wbits: wb,
            abits: ab,
            lsigned: ls,
            rsigned: rs,
        };
        let w = Arc::new(IntMatrix::random(rng, k, n, ab, rs));
        let backend = if rng.chance(0.5) {
            Backend::Engine
        } else {
            Backend::Sim
        };
        let prepared = s.matmul(prec).backend(backend).prepare(w.clone()).unwrap();
        let before = s.cache_stats();
        for _ in 0..2 {
            let x = IntMatrix::random(rng, m, k, wb, ls);
            let resp = prepared.execute(x.clone()).unwrap();
            assert_eq!(resp.result, oracle(&x, &w, prec), "case {case}");
            assert!(resp.rhs_cached, "case {case} reused the prepared packing");
        }
        let after = s.cache_stats();
        assert_eq!(after.misses, before.misses, "case {case}: zero repacks");
    });
}

#[test]
fn builder_errors_are_typed_and_pre_queue() {
    let s = session();
    // Precision rejected before anything is enqueued.
    let bad = Precision {
        wbits: 0,
        abits: 1,
        lsigned: false,
        rsigned: false,
    };
    match s.matmul(bad).run(IntMatrix::zeros(1, 1), IntMatrix::zeros(1, 1)) {
        Err(BismoError::PrecisionUnsupported(_)) => {}
        other => panic!("expected PrecisionUnsupported, got {other:?}"),
    }
    // Shape mismatch surfaces through the handle as a typed error.
    match s.run(
        IntMatrix::zeros(2, 3),
        IntMatrix::zeros(4, 2),
        Precision::unsigned(1, 1),
    ) {
        Err(BismoError::ShapeMismatch(_)) => {}
        other => panic!("expected ShapeMismatch, got {other:?}"),
    }
    // Weights out of declared range are caught at prepare time.
    match s.prepare(IntMatrix::from_slice(1, 1, &[100]), Precision::unsigned(2, 2)) {
        Err(BismoError::PrecisionUnsupported(_)) => {}
        other => panic!(
            "expected PrecisionUnsupported, got {:?}",
            other.err().map(|e| e.kind())
        ),
    }
    // The session still serves valid work afterwards.
    let ok = s
        .run(
            IntMatrix::from_slice(1, 1, &[1]),
            IntMatrix::from_slice(1, 1, &[1]),
            Precision::unsigned(1, 1),
        )
        .unwrap();
    assert_eq!(ok.result, IntMatrix::from_slice(1, 1, &[1]));
}

#[test]
fn variable_precision_override_packs_once_per_precision() {
    let s = session();
    let mut rng = Rng::new(0x1E9);
    let w = Arc::new(IntMatrix::random(&mut rng, 96, 4, 3, true));
    let base = Precision {
        wbits: 2,
        abits: 3,
        lsigned: false,
        rsigned: true,
    };
    let prepared = s.prepare(w.clone(), base).unwrap();
    let x = IntMatrix::random(&mut rng, 2, 96, 2, false);
    let expect = x.matmul(&w);
    // Base precision: already packed at prepare.
    assert_eq!(prepared.execute(x.clone()).unwrap().result, expect);
    // Override to a wider declared weight precision: one new packing...
    let wider = Precision {
        abits: 6,
        ..base
    };
    let m0 = s.cache_stats().misses;
    assert_eq!(prepared.execute_with(x.clone(), wider).unwrap().result, expect);
    assert_eq!(s.cache_stats().misses, m0 + 1, "new precision packs once");
    // ...and repeats at that precision are hits again.
    let r = prepared.execute_with(x.clone(), wider).unwrap();
    assert!(r.rhs_cached);
    assert_eq!(s.cache_stats().misses, m0 + 1, "second override reuses it");
}
