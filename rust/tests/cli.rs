//! CLI smoke tests: every subcommand runs and prints what it promises.

use std::process::Command;

fn bismo(args: &[&str]) -> (bool, String) {
    bismo_env(args, &[])
}

/// Spawn `bismo` with extra environment variables — the process-level
/// way to exercise `BISMO_SIMD`, free of the env races in-process env
/// mutation would cause across test threads.
fn bismo_env(args: &[&str], envs: &[(&str, &str)]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_bismo"))
        .args(args)
        .envs(envs.iter().map(|&(k, v)| (k, v)))
        .output()
        .expect("spawn bismo");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn quickstart_verifies() {
    let (ok, text) = bismo(&["quickstart"]);
    assert!(ok, "{text}");
    assert!(text.contains("verified OK"), "{text}");
}

#[test]
fn simulate_prints_report() {
    let (ok, text) = bismo(&[
        "simulate", "--instance", "2", "--m", "16", "--k", "512", "--n", "16",
        "--wbits", "3", "--abits", "2", "--signed",
    ]);
    assert!(ok, "{text}");
    for needle in ["cycles", "GOPS", "efficiency", "planes"] {
        assert!(text.contains(needle), "missing {needle}: {text}");
    }
}

#[test]
fn simulate_bit_skip_and_no_overlap() {
    let (ok, text) = bismo(&[
        "simulate", "--m", "8", "--k", "256", "--n", "8", "--bit-skip", "--no-overlap",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("verified"), "{text}");
}

#[test]
fn schedule_dumps_queues() {
    let (ok, text) = bismo(&["schedule", "--m", "4", "--k", "128", "--n", "4"]);
    assert!(ok, "{text}");
    for needle in ["fetch queue", "execute queue", "result queue", "RunExecute"] {
        assert!(text.contains(needle), "missing {needle}");
    }
}

#[test]
fn costmodel_power_synth_instances_info() {
    for cmd in ["costmodel", "power", "synth", "instances", "info"] {
        let (ok, text) = bismo(&[cmd]);
        assert!(ok, "{cmd}: {text}");
        assert!(text.len() > 50, "{cmd} output too short");
    }
}

#[test]
fn synth_single_dk() {
    let (ok, text) = bismo(&["synth", "--dk", "128"]);
    assert!(ok, "{text}");
    assert!(text.contains("DPU(Dk=128)"), "{text}");
}

#[test]
fn bench_quick_writes_json() {
    let out = std::env::temp_dir().join(format!("bismo_bench_{}.json", std::process::id()));
    let out_str = out.to_str().unwrap().to_string();
    let (ok, text) = bismo(&["bench", "--quick", "--threads", "2", "--out", &out_str]);
    assert!(ok, "{text}");
    assert!(text.contains("speedup"), "{text}");
    let json = std::fs::read_to_string(&out).expect("bench json written");
    let _ = std::fs::remove_file(&out);
    let doc = bismo::util::Json::parse(&json).expect("valid json");
    assert_eq!(
        doc.get("schema").and_then(|s| s.as_str()),
        Some("bismo-bench-gemm/v1")
    );
    assert_eq!(doc.get("mode").and_then(|s| s.as_str()), Some("quick"));
    // Every bench report records which SIMD tier produced it.
    let tier = doc.get("simd_tier").and_then(|s| s.as_str()).expect("simd_tier");
    assert!(["scalar", "neon", "avx2", "avx512"].contains(&tier), "{json}");
    let cases = doc.get("cases").and_then(|c| c.as_arr()).expect("cases");
    assert!(!cases.is_empty());
    for c in cases {
        for key in [
            "name",
            "binary_ops",
            "baseline_ns",
            "tiled_ns",
            "tiled_mt_ns",
            "speedup_1t",
        ] {
            assert!(c.get(key).is_some(), "case missing {key}: {json}");
        }
    }
    assert!(doc.get("headline").is_some(), "{json}");
}

#[test]
fn serve_bench_quick_writes_json_with_percentiles_and_cache_win() {
    let out = std::env::temp_dir().join(format!("bismo_serve_{}.json", std::process::id()));
    let out_str = out.to_str().unwrap().to_string();
    let (ok, text) = bismo(&[
        "serve-bench", "--quick", "--requests", "32", "--rate", "8000", "--workers", "2",
        "--batch", "4", "--out", &out_str,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("throughput"), "{text}");
    assert!(text.contains("packing cache"), "{text}");
    let json = std::fs::read_to_string(&out).expect("serve bench json written");
    let _ = std::fs::remove_file(&out);
    let doc = bismo::util::Json::parse(&json).expect("valid json");
    assert_eq!(
        doc.get("schema").and_then(|s| s.as_str()),
        Some("bismo-bench-serve/v1")
    );
    assert_eq!(doc.get("mode").and_then(|s| s.as_str()), Some("quick"));
    let lat = doc.get("latency_ns").expect("latency_ns");
    for key in ["p50", "p90", "p99", "max", "mean"] {
        let v = lat.get(key).and_then(|v| v.as_f64()).expect(key);
        assert!(v > 0.0, "{key} must be positive: {json}");
    }
    let thr = doc
        .get("throughput_rps")
        .and_then(|v| v.as_f64())
        .expect("throughput_rps");
    assert!(thr > 0.0);
    // The weight-reuse workload must show cache traffic and a measured
    // repack-avoidance comparison against the cache-off phase.
    let cache = doc.get("cache").expect("cache");
    assert!(cache.get("hits").and_then(|v| v.as_f64()).unwrap() > 0.0, "{json}");
    let pack = doc.get("pack").expect("pack");
    for key in [
        "cache_on_total_ns",
        "cache_off_total_ns",
        "avoided_ns",
        "avoided_ns_per_request",
        "speedup",
    ] {
        assert!(pack.get(key).is_some(), "pack missing {key}: {json}");
    }
    assert!(doc.get("cache_off").and_then(|c| c.get("latency_ns")).is_some());
}

#[test]
fn shard_bench_quick_writes_scaling_curve() {
    let out = std::env::temp_dir().join(format!("bismo_shard_{}.json", std::process::id()));
    let out_str = out.to_str().unwrap().to_string();
    // Tiny workload: this test checks plumbing and schema, not scaling.
    let (ok, text) = bismo(&[
        "shard-bench", "--quick", "--m", "32", "--k", "256", "--n", "32", "--reps", "2",
        "--max-shards", "2", "--out", &out_str,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("speedup"), "{text}");
    assert!(text.contains("auto under budget"), "{text}");
    let json = std::fs::read_to_string(&out).expect("shard bench json written");
    let _ = std::fs::remove_file(&out);
    let doc = bismo::util::Json::parse(&json).expect("valid json");
    assert_eq!(
        doc.get("schema").and_then(|s| s.as_str()),
        Some("bismo-bench-shard/v1")
    );
    assert_eq!(doc.get("mode").and_then(|s| s.as_str()), Some("quick"));
    let entries = doc.get("entries").and_then(|e| e.as_arr()).expect("entries");
    assert!(entries.len() >= 2, "{json}");
    for e in entries {
        for key in [
            "shards",
            "grid_rows",
            "grid_cols",
            "median_ns",
            "gops",
            "speedup_vs_single",
        ] {
            assert!(e.get(key).is_some(), "entry missing {key}: {json}");
        }
    }
    // The single-shard entry anchors the curve at speedup 1.0.
    let first = &entries[0];
    assert_eq!(first.get("shards").and_then(|v| v.as_f64()), Some(1.0));
    let auto = doc.get("auto").expect("auto");
    for key in ["shards", "dm", "dk", "dn", "total_luts", "total_brams"] {
        assert!(auto.get(key).is_some(), "auto missing {key}: {json}");
    }
    assert!(doc.get("headline").and_then(|h| h.get("best_speedup")).is_some());
}

#[test]
fn cnn_bench_quick_writes_json() {
    let out = std::env::temp_dir().join(format!("bismo_cnn_{}.json", std::process::id()));
    let out_str = out.to_str().unwrap().to_string();
    // Minimal batch/reps: this test checks plumbing and schema; the CI
    // smoke step runs the real quick suite.
    let (ok, text) = bismo(&[
        "cnn-bench", "--quick", "--batch", "1", "--reps", "1", "--out", &out_str,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("inferences/s"), "{text}");
    let json = std::fs::read_to_string(&out).expect("cnn bench json written");
    let _ = std::fs::remove_file(&out);
    let doc = bismo::util::Json::parse(&json).expect("valid json");
    assert_eq!(
        doc.get("schema").and_then(|s| s.as_str()),
        Some("bismo-bench-cnn/v1")
    );
    assert_eq!(doc.get("mode").and_then(|s| s.as_str()), Some("quick"));
    let layers = doc.get("layers").and_then(|l| l.as_arr()).expect("layers");
    // conv1/conv2 for both lowerings + the dense head once.
    assert_eq!(layers.len(), 5, "{json}");
    for l in layers {
        for key in [
            "name",
            "lowering",
            "m",
            "k",
            "n",
            "activation_bits",
            "weight_bits",
            "gemms",
            "binary_ops",
            "engine_exec_ns",
            "sim_cycles",
        ] {
            assert!(l.get(key).is_some(), "layer missing {key}: {json}");
        }
        let cycles = l.get("sim_cycles").and_then(|v| v.as_f64()).unwrap();
        assert!(cycles > 0.0, "sim cycles must be positive: {json}");
    }
    let e2e = doc.get("end_to_end").expect("end_to_end");
    for mode in ["im2col", "kn2row"] {
        let m = e2e.get(mode).expect(mode);
        assert!(m.get("inferences_per_s").and_then(|v| v.as_f64()).unwrap() > 0.0);
        assert!(m.get("sim_total_cycles").and_then(|v| v.as_f64()).unwrap() > 0.0);
    }
    assert!(doc.get("headline").and_then(|h| h.get("inferences_per_s")).is_some());
}

/// Write a minimal-but-schema-complete BENCH_gemm.json for bench-check
/// tests, with one case named `c1` at the given speedup.
fn write_bench_file(tag: &str, speedup: f64, binary_ops: f64) -> String {
    let name = format!("bismo_check_{}_{}.json", tag, std::process::id());
    let path = std::env::temp_dir().join(name);
    let text = format!(
        r#"{{
  "schema": "bismo-bench-gemm/v1",
  "mode": "quick",
  "threads": 2,
  "generated_unix": 0,
  "cases": [
    {{
      "name": "c1", "m": 8, "k": 64, "n": 8, "wbits": 2, "abits": 2, "signed": false,
      "binary_ops": {binary_ops},
      "baseline_ns": 1000, "tiled_ns": 500, "tiled_mt_ns": 250,
      "baseline_gops": 1.0, "tiled_gops": 2.0, "tiled_mt_gops": 4.0,
      "speedup_1t": {speedup}, "speedup_mt": 4.0
    }}
  ],
  "headline": {{ "case": "c1", "speedup_1t": {speedup} }}
}}
"#
    );
    std::fs::write(&path, text).expect("write bench file");
    path.to_str().unwrap().to_string()
}

#[test]
fn bench_check_passes_within_tolerance_and_fails_beyond() {
    let base = write_bench_file("base", 2.0, 65536.0);
    let same = write_bench_file("same", 1.9, 65536.0);
    let (ok, text) = bismo(&[
        "bench-check", "--baseline", &base, "--current", &same, "--tolerance", "0.35",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("bench-check OK"), "{text}");
    // A 2.0 -> 1.0 speedup collapse is beyond a 35% tolerance.
    let slow = write_bench_file("slow", 1.0, 65536.0);
    let (ok, text) = bismo(&[
        "bench-check", "--baseline", &base, "--current", &slow, "--tolerance", "0.35",
    ]);
    assert!(!ok, "regression must fail the gate: {text}");
    assert!(text.contains("REGRESSION"), "{text}");
    for p in [base, same, slow] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn bench_check_rejects_schema_drift() {
    // Same case name but different workload identity (binary_ops):
    // the comparison is meaningless, so the gate must fail loudly.
    let base = write_bench_file("dbase", 2.0, 65536.0);
    let drifted = write_bench_file("ddrift", 2.0, 131072.0);
    let (ok, text) = bismo(&["bench-check", "--baseline", &base, "--current", &drifted]);
    assert!(!ok, "{text}");
    assert!(text.contains("schema drift"), "{text}");
    // Missing --current is a parse error, not a panic.
    let (ok, text) = bismo(&["bench-check", "--baseline", &base]);
    assert!(!ok);
    assert!(text.contains("--current"), "{text}");
    // An explicit but unparsable tolerance fails instead of silently
    // loosening the gate to the default.
    let (ok, text) = bismo(&[
        "bench-check", "--baseline", &base, "--current", &base, "--tolerance", "10%",
    ]);
    assert!(!ok);
    assert!(text.contains("bad --tolerance"), "{text}");
    // The committed CI baseline itself must be schema-complete: checked
    // against itself it passes at any tolerance.
    let committed = concat!(env!("CARGO_MANIFEST_DIR"), "/../ci/bench_baseline.json");
    let (ok, text) = bismo(&[
        "bench-check", "--baseline", committed, "--current", committed, "--tolerance", "0.0",
    ]);
    assert!(ok, "committed baseline must self-validate: {text}");
    for p in [base, drifted] {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn tune_quick_persists_a_profile_and_self_validates_through_bench_check() {
    let base = std::env::temp_dir().join(format!("bismo_tune_cli_{}", std::process::id()));
    let dir = base.join("profiles");
    let out = base.join("BENCH_tune.json");
    let dir_str = dir.to_str().unwrap().to_string();
    let out_str = out.to_str().unwrap().to_string();
    let (ok, text) = bismo(&[
        "tune", "--quick", "--threads", "2", "--out", &out_str, "--dir", &dir_str,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("tuned picks"), "{text}");
    let json = std::fs::read_to_string(&out).expect("tune json written");
    let doc = bismo::util::Json::parse(&json).expect("valid json");
    assert_eq!(
        doc.get("schema").and_then(|s| s.as_str()),
        Some("bismo-tune/v1")
    );
    assert_eq!(doc.get("mode").and_then(|s| s.as_str()), Some("quick"));
    let key = doc
        .get("profile_key")
        .and_then(|s| s.as_str())
        .expect("profile_key present");
    // The profile landed at its content address and re-parses as the
    // runtime will read it.
    let profile_path = dir.join(format!("bismo-tune-{key}.json"));
    assert!(profile_path.exists(), "{}", profile_path.display());
    let classes = doc.get("classes").and_then(|c| c.as_arr()).unwrap();
    assert_eq!(classes.len(), 5, "{json}");
    for class in classes {
        let speedup = class.get("speedup").and_then(|s| s.as_f64()).unwrap();
        assert!(
            speedup >= 1.0,
            "tuned pick must be at least the measured default: {json}"
        );
    }
    // The tune report self-validates through the regression gate.
    let (ok, text) = bismo(&[
        "bench-check", "--baseline", &out_str, "--current", &out_str, "--tolerance", "0.0",
    ]);
    assert!(ok, "tune report must self-validate: {text}");
    assert!(text.contains("bench-check OK"), "{text}");
    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn unknown_instance_is_a_clean_error_not_a_panic() {
    // `try_instance` behind the CLI: a bad Table IV id must exit 1 with
    // a typed-error message, not a panic/abort backtrace.
    let (ok, text) = bismo(&["simulate", "--instance", "9", "--m", "4", "--k", "64", "--n", "4"]);
    assert!(!ok);
    assert!(text.contains("error:"), "{text}");
    assert!(text.contains("instances 1..=6"), "{text}");
    assert!(!text.contains("panicked"), "{text}");
    // Non-numeric ids are parse errors.
    let (ok2, text2) = bismo(&["costmodel", "--instance", "banana"]);
    assert!(!ok2);
    assert!(text2.contains("bad --instance"), "{text2}");
}

#[test]
fn unknown_command_usage() {
    let (ok, text) = bismo(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("usage:"), "{text}");
}

#[test]
fn bismo_simd_garbage_is_a_typed_cli_error() {
    let (ok, text) = bismo_env(&["bench", "--quick"], &[("BISMO_SIMD", "warp9")]);
    assert!(!ok, "garbage BISMO_SIMD must fail: {text}");
    assert!(text.contains("invalid config"), "{text}");
    assert!(text.contains("BISMO_SIMD"), "{text}");
    assert!(!text.contains("panicked"), "typed error, not a panic: {text}");
    // The serving path rejects it through the same typed error.
    let (ok, text) = bismo_env(
        &["serve-bench", "--quick", "--requests", "4", "--rate", "8000"],
        &[("BISMO_SIMD", "avx1024")],
    );
    assert!(!ok, "{text}");
    assert!(text.contains("invalid config"), "{text}");
}

#[test]
fn bismo_simd_scalar_forces_the_scalar_tier_end_to_end() {
    let out = std::env::temp_dir().join(format!("bismo_bench_scalar_{}.json", std::process::id()));
    let out_str = out.to_str().unwrap().to_string();
    let (ok, text) = bismo_env(
        &["bench", "--quick", "--threads", "2", "--out", &out_str],
        &[("BISMO_SIMD", "scalar")],
    );
    assert!(ok, "{text}");
    assert!(text.contains("simd tier: scalar"), "{text}");
    let json = std::fs::read_to_string(&out).expect("bench json written");
    let _ = std::fs::remove_file(&out);
    let doc = bismo::util::Json::parse(&json).expect("valid json");
    assert_eq!(doc.get("simd_tier").and_then(|s| s.as_str()), Some("scalar"), "{json}");
}

#[test]
fn info_reports_the_dispatch_tier_and_override_knob() {
    let (ok, text) = bismo(&["info"]);
    assert!(ok, "{text}");
    assert!(text.contains("simd tier:"), "{text}");
    assert!(text.contains("BISMO_SIMD"), "{text}");
    // Tuned-profile status is always reported (loaded, none, or
    // rejected), including the directory override knob when absent.
    assert!(text.contains("tuned profile:"), "{text}");
    // Forcing a tier is reflected verbatim.
    let (ok, text) = bismo_env(&["info"], &[("BISMO_SIMD", "scalar")]);
    assert!(ok, "{text}");
    assert!(text.contains("simd tier: scalar"), "{text}");
}

#[test]
fn attn_bench_quick_writes_json() {
    let out = std::env::temp_dir().join(format!("bismo_attn_{}.json", std::process::id()));
    let out_str = out.to_str().unwrap().to_string();
    // Minimal seq/requests/reps: this test checks plumbing and schema;
    // the CI smoke step runs the real quick suite.
    let (ok, text) = bismo(&[
        "attn-bench", "--quick", "--seq", "4", "--requests", "3", "--reps", "1",
        "--out", &out_str,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("tokens/s"), "{text}");
    let json = std::fs::read_to_string(&out).expect("attn bench json written");
    let _ = std::fs::remove_file(&out);
    let doc = bismo::util::Json::parse(&json).expect("valid json");
    assert_eq!(
        doc.get("schema").and_then(|s| s.as_str()),
        Some("bismo-bench-attn/v1")
    );
    assert_eq!(doc.get("mode").and_then(|s| s.as_str()), Some("quick"));
    // Six GEMM layers, each with its shape identity.
    let layers = doc.get("layers").and_then(|l| l.as_arr()).expect("layers");
    assert_eq!(layers.len(), 6, "{json}");
    for l in layers {
        for key in ["name", "gemms", "m", "k", "n", "activation_bits", "weight_bits"] {
            assert!(l.get(key).is_some(), "layer missing {key}: {json}");
        }
    }
    // All four arms with throughput + accuracy; the exact arms report
    // accuracy 1.0 (they are gated bit-exact before timing).
    for arm in ["static_full", "static_low", "adaptive", "adaptive_entropy"] {
        let a = doc
            .get("arms")
            .and_then(|m| m.get(arm))
            .unwrap_or_else(|| panic!("arm {arm} missing: {json}"));
        let rate = a.get("tokens_per_s").and_then(|v| v.as_f64()).unwrap();
        assert!(rate > 0.0, "{arm} rate: {json}");
        assert!(a.get("accuracy_proxy").is_some(), "{arm}: {json}");
    }
    let acc = doc
        .get("arms")
        .and_then(|m| m.get("adaptive"))
        .and_then(|a| a.get("accuracy_proxy"))
        .and_then(|v| v.as_f64())
        .unwrap();
    assert_eq!(acc, 1.0, "range-adaptive arm must stay bit-exact: {json}");
    // The policy decision log and the deterministic sim cycle section.
    let decisions = doc.get("decisions").and_then(|d| d.as_arr()).expect("decisions");
    assert!(!decisions.is_empty(), "{json}");
    let sim = doc.get("sim").expect("sim section");
    let ratio = sim.get("cycle_ratio").and_then(|v| v.as_f64()).unwrap();
    assert!(
        ratio >= 1.0,
        "adaptive must not cost more sim cycles than static: {json}"
    );
    for key in [
        "adaptive_speedup",
        "sim_cycle_ratio",
        "accuracy_proxy",
        "accuracy_floor",
        "tokens_per_s",
    ] {
        assert!(
            doc.get("headline").and_then(|h| h.get(key)).is_some(),
            "headline missing {key}: {json}"
        );
    }
}

#[test]
fn bench_check_attn_gates_regressions_and_drift() {
    let dir = std::env::temp_dir();
    let base = dir.join(format!("bismo_attn_base_{}.json", std::process::id()));
    let cur = dir.join(format!("bismo_attn_cur_{}.json", std::process::id()));
    let base_str = base.to_str().unwrap().to_string();
    let cur_str = cur.to_str().unwrap().to_string();
    let (ok, text) = bismo(&[
        "attn-bench", "--quick", "--seq", "4", "--requests", "3", "--reps", "1",
        "--out", &base_str,
    ]);
    assert!(ok, "{text}");

    // Self-comparison passes: identical identity, identical metrics.
    let (ok, text) = bismo(&[
        "bench-check", "--baseline", &base_str, "--current", &base_str,
        "--tolerance", "0.5",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("bench-check OK"), "{text}");

    // A sabotaged adaptive_speedup regresses; a drifted seq is schema
    // drift. Both must fail loudly.
    let json = std::fs::read_to_string(&base).unwrap();
    let mut doc = bismo::util::Json::parse(&json).unwrap();
    if let bismo::util::Json::Obj(root) = &mut doc {
        if let Some(bismo::util::Json::Obj(headline)) = root.get_mut("headline") {
            headline.insert("adaptive_speedup".into(), bismo::util::Json::num(0.01));
        }
    }
    std::fs::write(&cur, doc.pretty(2)).unwrap();
    let (ok, text) = bismo(&[
        "bench-check", "--baseline", &base_str, "--current", &cur_str,
        "--tolerance", "0.35",
    ]);
    assert!(!ok, "a collapsed adaptive speedup must fail the gate: {text}");
    assert!(text.contains("REGRESSION"), "{text}");

    let mut doc = bismo::util::Json::parse(&json).unwrap();
    if let bismo::util::Json::Obj(root) = &mut doc {
        root.insert("seq".into(), bismo::util::Json::num(999.0));
    }
    std::fs::write(&cur, doc.pretty(2)).unwrap();
    let (ok, text) = bismo(&[
        "bench-check", "--baseline", &base_str, "--current", &cur_str,
    ]);
    assert!(!ok, "workload identity drift must fail the gate: {text}");
    assert!(text.contains("schema drift"), "{text}");

    let _ = std::fs::remove_file(&base);
    let _ = std::fs::remove_file(&cur);
}
