//! CLI smoke tests: every subcommand runs and prints what it promises.

use std::process::Command;

fn bismo(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_bismo"))
        .args(args)
        .output()
        .expect("spawn bismo");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn quickstart_verifies() {
    let (ok, text) = bismo(&["quickstart"]);
    assert!(ok, "{text}");
    assert!(text.contains("verified OK"), "{text}");
}

#[test]
fn simulate_prints_report() {
    let (ok, text) = bismo(&[
        "simulate", "--instance", "2", "--m", "16", "--k", "512", "--n", "16",
        "--wbits", "3", "--abits", "2", "--signed",
    ]);
    assert!(ok, "{text}");
    for needle in ["cycles", "GOPS", "efficiency", "planes"] {
        assert!(text.contains(needle), "missing {needle}: {text}");
    }
}

#[test]
fn simulate_bit_skip_and_no_overlap() {
    let (ok, text) = bismo(&[
        "simulate", "--m", "8", "--k", "256", "--n", "8", "--bit-skip", "--no-overlap",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("verified"), "{text}");
}

#[test]
fn schedule_dumps_queues() {
    let (ok, text) = bismo(&["schedule", "--m", "4", "--k", "128", "--n", "4"]);
    assert!(ok, "{text}");
    for needle in ["fetch queue", "execute queue", "result queue", "RunExecute"] {
        assert!(text.contains(needle), "missing {needle}");
    }
}

#[test]
fn costmodel_power_synth_instances_info() {
    for cmd in ["costmodel", "power", "synth", "instances", "info"] {
        let (ok, text) = bismo(&[cmd]);
        assert!(ok, "{cmd}: {text}");
        assert!(text.len() > 50, "{cmd} output too short");
    }
}

#[test]
fn synth_single_dk() {
    let (ok, text) = bismo(&["synth", "--dk", "128"]);
    assert!(ok, "{text}");
    assert!(text.contains("DPU(Dk=128)"), "{text}");
}

#[test]
fn unknown_command_usage() {
    let (ok, text) = bismo(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("usage:"), "{text}");
}
