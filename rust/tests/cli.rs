//! CLI smoke tests: every subcommand runs and prints what it promises.

use std::process::Command;

fn bismo(args: &[&str]) -> (bool, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_bismo"))
        .args(args)
        .output()
        .expect("spawn bismo");
    let text = format!(
        "{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    (out.status.success(), text)
}

#[test]
fn quickstart_verifies() {
    let (ok, text) = bismo(&["quickstart"]);
    assert!(ok, "{text}");
    assert!(text.contains("verified OK"), "{text}");
}

#[test]
fn simulate_prints_report() {
    let (ok, text) = bismo(&[
        "simulate", "--instance", "2", "--m", "16", "--k", "512", "--n", "16",
        "--wbits", "3", "--abits", "2", "--signed",
    ]);
    assert!(ok, "{text}");
    for needle in ["cycles", "GOPS", "efficiency", "planes"] {
        assert!(text.contains(needle), "missing {needle}: {text}");
    }
}

#[test]
fn simulate_bit_skip_and_no_overlap() {
    let (ok, text) = bismo(&[
        "simulate", "--m", "8", "--k", "256", "--n", "8", "--bit-skip", "--no-overlap",
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("verified"), "{text}");
}

#[test]
fn schedule_dumps_queues() {
    let (ok, text) = bismo(&["schedule", "--m", "4", "--k", "128", "--n", "4"]);
    assert!(ok, "{text}");
    for needle in ["fetch queue", "execute queue", "result queue", "RunExecute"] {
        assert!(text.contains(needle), "missing {needle}");
    }
}

#[test]
fn costmodel_power_synth_instances_info() {
    for cmd in ["costmodel", "power", "synth", "instances", "info"] {
        let (ok, text) = bismo(&[cmd]);
        assert!(ok, "{cmd}: {text}");
        assert!(text.len() > 50, "{cmd} output too short");
    }
}

#[test]
fn synth_single_dk() {
    let (ok, text) = bismo(&["synth", "--dk", "128"]);
    assert!(ok, "{text}");
    assert!(text.contains("DPU(Dk=128)"), "{text}");
}

#[test]
fn bench_quick_writes_json() {
    let out = std::env::temp_dir().join(format!("bismo_bench_{}.json", std::process::id()));
    let out_str = out.to_str().unwrap().to_string();
    let (ok, text) = bismo(&["bench", "--quick", "--threads", "2", "--out", &out_str]);
    assert!(ok, "{text}");
    assert!(text.contains("speedup"), "{text}");
    let json = std::fs::read_to_string(&out).expect("bench json written");
    let _ = std::fs::remove_file(&out);
    let doc = bismo::util::Json::parse(&json).expect("valid json");
    assert_eq!(
        doc.get("schema").and_then(|s| s.as_str()),
        Some("bismo-bench-gemm/v1")
    );
    assert_eq!(doc.get("mode").and_then(|s| s.as_str()), Some("quick"));
    let cases = doc.get("cases").and_then(|c| c.as_arr()).expect("cases");
    assert!(!cases.is_empty());
    for c in cases {
        for key in [
            "name",
            "binary_ops",
            "baseline_ns",
            "tiled_ns",
            "tiled_mt_ns",
            "speedup_1t",
        ] {
            assert!(c.get(key).is_some(), "case missing {key}: {json}");
        }
    }
    assert!(doc.get("headline").is_some(), "{json}");
}

#[test]
fn serve_bench_quick_writes_json_with_percentiles_and_cache_win() {
    let out = std::env::temp_dir().join(format!("bismo_serve_{}.json", std::process::id()));
    let out_str = out.to_str().unwrap().to_string();
    let (ok, text) = bismo(&[
        "serve-bench", "--quick", "--requests", "32", "--rate", "8000", "--workers", "2",
        "--batch", "4", "--out", &out_str,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("throughput"), "{text}");
    assert!(text.contains("packing cache"), "{text}");
    let json = std::fs::read_to_string(&out).expect("serve bench json written");
    let _ = std::fs::remove_file(&out);
    let doc = bismo::util::Json::parse(&json).expect("valid json");
    assert_eq!(
        doc.get("schema").and_then(|s| s.as_str()),
        Some("bismo-bench-serve/v1")
    );
    assert_eq!(doc.get("mode").and_then(|s| s.as_str()), Some("quick"));
    let lat = doc.get("latency_ns").expect("latency_ns");
    for key in ["p50", "p90", "p99", "max", "mean"] {
        let v = lat.get(key).and_then(|v| v.as_f64()).expect(key);
        assert!(v > 0.0, "{key} must be positive: {json}");
    }
    let thr = doc
        .get("throughput_rps")
        .and_then(|v| v.as_f64())
        .expect("throughput_rps");
    assert!(thr > 0.0);
    // The weight-reuse workload must show cache traffic and a measured
    // repack-avoidance comparison against the cache-off phase.
    let cache = doc.get("cache").expect("cache");
    assert!(cache.get("hits").and_then(|v| v.as_f64()).unwrap() > 0.0, "{json}");
    let pack = doc.get("pack").expect("pack");
    for key in [
        "cache_on_total_ns",
        "cache_off_total_ns",
        "avoided_ns",
        "avoided_ns_per_request",
        "speedup",
    ] {
        assert!(pack.get(key).is_some(), "pack missing {key}: {json}");
    }
    assert!(doc.get("cache_off").and_then(|c| c.get("latency_ns")).is_some());
}

#[test]
fn shard_bench_quick_writes_scaling_curve() {
    let out = std::env::temp_dir().join(format!("bismo_shard_{}.json", std::process::id()));
    let out_str = out.to_str().unwrap().to_string();
    // Tiny workload: this test checks plumbing and schema, not scaling.
    let (ok, text) = bismo(&[
        "shard-bench", "--quick", "--m", "32", "--k", "256", "--n", "32", "--reps", "2",
        "--max-shards", "2", "--out", &out_str,
    ]);
    assert!(ok, "{text}");
    assert!(text.contains("speedup"), "{text}");
    assert!(text.contains("auto under budget"), "{text}");
    let json = std::fs::read_to_string(&out).expect("shard bench json written");
    let _ = std::fs::remove_file(&out);
    let doc = bismo::util::Json::parse(&json).expect("valid json");
    assert_eq!(
        doc.get("schema").and_then(|s| s.as_str()),
        Some("bismo-bench-shard/v1")
    );
    assert_eq!(doc.get("mode").and_then(|s| s.as_str()), Some("quick"));
    let entries = doc.get("entries").and_then(|e| e.as_arr()).expect("entries");
    assert!(entries.len() >= 2, "{json}");
    for e in entries {
        for key in [
            "shards",
            "grid_rows",
            "grid_cols",
            "median_ns",
            "gops",
            "speedup_vs_single",
        ] {
            assert!(e.get(key).is_some(), "entry missing {key}: {json}");
        }
    }
    // The single-shard entry anchors the curve at speedup 1.0.
    let first = &entries[0];
    assert_eq!(first.get("shards").and_then(|v| v.as_f64()), Some(1.0));
    let auto = doc.get("auto").expect("auto");
    for key in ["shards", "dm", "dk", "dn", "total_luts", "total_brams"] {
        assert!(auto.get(key).is_some(), "auto missing {key}: {json}");
    }
    assert!(doc.get("headline").and_then(|h| h.get("best_speedup")).is_some());
}

#[test]
fn unknown_instance_is_a_clean_error_not_a_panic() {
    // `try_instance` behind the CLI: a bad Table IV id must exit 1 with
    // a typed-error message, not a panic/abort backtrace.
    let (ok, text) = bismo(&["simulate", "--instance", "9", "--m", "4", "--k", "64", "--n", "4"]);
    assert!(!ok);
    assert!(text.contains("error:"), "{text}");
    assert!(text.contains("instances 1..=6"), "{text}");
    assert!(!text.contains("panicked"), "{text}");
    // Non-numeric ids are parse errors.
    let (ok2, text2) = bismo(&["costmodel", "--instance", "banana"]);
    assert!(!ok2);
    assert!(text2.contains("bad --instance"), "{text2}");
}

#[test]
fn unknown_command_usage() {
    let (ok, text) = bismo(&["frobnicate"]);
    assert!(!ok);
    assert!(text.contains("usage:"), "{text}");
}
