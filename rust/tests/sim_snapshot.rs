//! Snapshot/replay: suspended simulations must resume bit- and
//! cycle-exactly, snapshots must survive a JSON roundtrip, and the
//! committed golden fixture must match what this build produces.

use bismo::arch::{BismoConfig, PYNQ_Z1};
use bismo::bitmatrix::dram::DramImage;
use bismo::fuzz::{generate_legal_program, golden_snapshot_report, random_fuzz_config};
use bismo::sim::{SimSnapshot, Simulation, StepOutcome};
use bismo::util::{splitmix64, Json, Rng};

fn seeded_dram(seed: u64, len: usize) -> DramImage {
    let mut img = DramImage::new(len);
    for i in 0..(len as u64 / 8) {
        img.write_u64(i * 8, splitmix64(seed ^ i));
    }
    img
}

/// Property: for random programs and random suspend points, suspending,
/// serializing, restoring and resuming converges to the exact final
/// state of the uninterrupted run.
#[test]
fn random_suspend_points_resume_bit_and_cycle_exact() {
    for case in 0..12u64 {
        let mut rng = Rng::new(0x5EED ^ case);
        let cfg = random_fuzz_config(&mut rng);
        let prog = generate_legal_program(&mut rng, &cfg, 1 << 16);

        let mut reference = Simulation::new(cfg, &PYNQ_Z1, seeded_dram(case, 1 << 16)).unwrap();
        let ref_stats = reference.run(&prog).unwrap();

        let total = prog.stats().total as u64;
        let cut = rng.below(total); // strictly before completion
        let mut sim = Simulation::new(cfg, &PYNQ_Z1, seeded_dram(case, 1 << 16)).unwrap();
        sim.begin(&prog).unwrap();
        assert_eq!(
            sim.step(&prog, cut).unwrap(),
            StepOutcome::Suspended,
            "case {case}: cut {cut} of {total} must suspend"
        );

        // Serialize, drop the live simulator, restore from text alone.
        let text = sim.snapshot().to_json();
        drop(sim);
        let snap = SimSnapshot::from_json(&text).unwrap();
        let mut resumed = Simulation::restore(&snap, &PYNQ_Z1).unwrap();
        match resumed.step(&prog, u64::MAX).unwrap() {
            StepOutcome::Completed(stats) => {
                assert_eq!(stats, ref_stats, "case {case}: stats diverged after resume");
            }
            StepOutcome::Suspended => panic!("case {case}: unbounded resume suspended"),
        }
        assert_eq!(
            resumed.dram.as_bytes(),
            reference.dram.as_bytes(),
            "case {case}: DRAM contents diverged after resume"
        );
    }
}

/// A snapshot of one config cannot be restored into a different world:
/// mismatched programs are rejected by the fingerprint check.
#[test]
fn restored_simulation_rejects_a_different_program() {
    let mut rng = Rng::new(77);
    let cfg = random_fuzz_config(&mut rng);
    let prog = generate_legal_program(&mut rng, &cfg, 1 << 16);
    let mut sim = Simulation::new(cfg, &PYNQ_Z1, seeded_dram(7, 1 << 16)).unwrap();
    sim.begin(&prog).unwrap();
    if sim.step(&prog, 1).unwrap() == StepOutcome::Suspended {
        let snap = sim.snapshot();
        let mut restored = Simulation::restore(&snap, &PYNQ_Z1).unwrap();
        let other = generate_legal_program(&mut rng, &cfg, 1 << 16);
        assert!(
            restored.step(&other, u64::MAX).is_err(),
            "stepping a restored sim with a different program must fail"
        );
    }
}

/// Golden fixture gate (mirrors `bismo snapshot` in CI): the
/// deterministic report this build produces must match the committed
/// baseline, unless the baseline is still the bootstrap placeholder.
#[test]
fn golden_fixture_matches_committed_baseline() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../ci/sim_snapshots.json");
    let baseline_text = std::fs::read_to_string(path).expect("ci/sim_snapshots.json must exist");
    let baseline = Json::parse(&baseline_text).expect("golden baseline must be valid JSON");
    assert_eq!(
        baseline.get("schema").and_then(Json::as_str),
        Some("bismo-sim-golden/v1"),
        "golden baseline schema tag"
    );
    if baseline.get("status").and_then(Json::as_str) == Some("bootstrap") {
        // Not yet ratcheted: `bismo snapshot --regen` on a trusted
        // build commits the first real baseline.
        return;
    }
    let current = Json::parse(&golden_snapshot_report().unwrap()).unwrap();
    assert_eq!(
        baseline.dump(),
        current.dump(),
        "snapshot/replay behaviour drifted from the committed golden \
         (regenerate deliberately with `bismo snapshot --regen`)"
    );
}

/// The config is carried inside the snapshot: restore works without
/// re-supplying it, and a default-config snapshot of a fresh simulator
/// roundtrips through JSON unchanged.
#[test]
fn fresh_simulation_snapshot_roundtrips() {
    let cfg = BismoConfig::small();
    let sim = Simulation::new(cfg, &PYNQ_Z1, DramImage::new(4096)).unwrap();
    let snap = sim.snapshot();
    let text = snap.to_json();
    let back = SimSnapshot::from_json(&text).unwrap();
    assert_eq!(back.to_json(), text, "JSON form must be a fixed point");
    let restored = Simulation::restore(&back, &PYNQ_Z1).unwrap();
    assert_eq!(restored.config(), &cfg);
}
