//! End-to-end tests for the served quantized attention block: the
//! [`Session::attn`] path must be bit-exact against the pure-i64
//! reference forward pass on *both* backends at every per-matrix
//! precision combination, stay bit-exact under the exactness-preserving
//! adaptive policy, flag (and bound) the damage of a lossy one, reuse
//! the weight-stationary cache across executes, and fail typed.

use bismo::api::{Backend, BismoError, Session};
use bismo::bitmatrix::IntMatrix;
use bismo::qnn::policy::clip_unsigned;
use bismo::qnn::{AttnSpec, AttnWeightBits, ClampPolicy, QnnAttn, RangeAdaptivePolicy};
use bismo::util::Rng;

const SPEC: AttnSpec = AttnSpec {
    d_model: 8,
    heads: 2,
    d_ff: 12,
    max_seq: 6,
};

fn session() -> Session {
    Session::with_defaults().unwrap()
}

#[test]
fn block_is_bit_exact_on_both_backends_across_precisions() {
    let s = session();
    let mut rng = Rng::new(0xA77);
    let flat = |b| AttnWeightBits {
        proj: b,
        out: b,
        ffn1: b,
        ffn2: b,
    };
    let combos: [(u32, AttnWeightBits); 4] = [
        (2, flat(2)),
        (3, AttnWeightBits::default()),
        (1, flat(1)),
        (
            3,
            AttnWeightBits {
                proj: 1,
                out: 2,
                ffn1: 1,
                ffn2: 2,
            },
        ),
    ];
    for (i, (abits, wbits)) in combos.into_iter().enumerate() {
        let model = QnnAttn::random(0x5EED + i as u64, SPEC, abits, wbits);
        // A full-length input and the seq=1 edge case.
        for seq in [SPEC.max_seq, 1] {
            let x = model.random_input(&mut rng, seq, abits);
            let want = model.forward_reference(&x).unwrap();
            for backend in [Backend::Engine, Backend::Sim] {
                let prepared = s.attn(&model).backend(backend).prepare().unwrap();
                let resp = prepared.execute(&x).unwrap();
                assert_eq!(
                    resp.output,
                    want,
                    "combo {i} (abits={abits}), seq {seq}, {}",
                    backend.name()
                );
                assert_eq!(resp.gemms.len(), model.gemms_per_pass());
                assert!(resp.decisions.is_empty(), "static path consults no policy");
                assert_eq!(
                    resp.sim_cycles().is_some(),
                    backend == Backend::Sim,
                    "cycles come from the simulator only"
                );
            }
        }
    }
}

#[test]
fn range_adaptive_policy_is_bit_exact_at_less_bitplane_work() {
    let s = session();
    let mut rng = Rng::new(0xA78);
    let model = QnnAttn::random(7, SPEC, 3, AttnWeightBits::default());
    let prepared = s.attn(&model).prepare().unwrap();
    // Calibrated for 3-bit activations, fed a request that only uses 1
    // bit: the range policy shrinks widths to what the operands hold.
    let x = model.random_input(&mut rng, 4, 1);
    let static_resp = prepared.execute(&x).unwrap();
    let adaptive = prepared
        .execute_with_policy(&x, &RangeAdaptivePolicy::default())
        .unwrap();
    assert_eq!(adaptive.output, static_resp.output, "exactness-preserving");
    assert_eq!(adaptive.gemms.len(), model.gemms_per_pass());
    assert!(!adaptive.decisions.is_empty(), "decisions are logged");
    assert!(
        adaptive.decisions.iter().all(|d| !d.clip),
        "the range policy never clips"
    );
    assert!(
        adaptive
            .decisions
            .iter()
            .any(|d| d.chosen_bits < d.base_bits),
        "a 1-bit request must shed declared bit planes somewhere"
    );
    assert!(
        adaptive.mean_lhs_bits() < static_resp.mean_lhs_bits(),
        "adaptive {} !< static {}",
        adaptive.mean_lhs_bits(),
        static_resp.mean_lhs_bits()
    );
    // Decisions name real layers and sides.
    for d in &adaptive.decisions {
        assert!(
            matches!(d.layer, "qkv" | "scores" | "attn_v" | "out" | "ffn1" | "ffn2"),
            "{}",
            d.layer
        );
        assert!(matches!(d.side, "lhs" | "rhs"), "{}", d.side);
    }
}

#[test]
fn clamp_policy_flags_clipping_and_computes_the_clipped_product() {
    let s = session();
    let model = QnnAttn::random(11, SPEC, 3, AttnWeightBits::default());
    let prepared = s.attn(&model).prepare().unwrap();
    // Saturated 3-bit input, clamped to 1 bit: lossy by construction.
    let x = IntMatrix::from_fn(4, SPEC.d_model, |_, _| 7);
    let resp = prepared.execute_with_policy(&x, &ClampPolicy { bits: 1 }).unwrap();
    assert!(
        resp.decisions.iter().any(|d| d.clip && d.chosen_bits == 1),
        "clipping is flagged per decision"
    );
    // The first projection GEMM served exactly the *clipped* operand —
    // the clip is an explicit policy action, not silent truncation...
    let q = &resp.gemms[0];
    assert_eq!(q.layer, "qkv");
    assert_eq!(q.prec.wbits, 1);
    assert_eq!(q.resp.result, clip_unsigned(&x, 1).matmul(model.weight("wq")));
    // ...and it genuinely diverges from the unclipped product.
    assert_ne!(q.resp.result, x.matmul(model.weight("wq")));
}

#[test]
fn prepared_weights_are_served_from_the_cache() {
    let s = session();
    let mut rng = Rng::new(0xA79);
    let model = QnnAttn::random(13, SPEC, 2, AttnWeightBits::default());
    let prepared = s.attn(&model).prepare().unwrap();
    let hits0 = s.cache_stats().hits;
    let x1 = model.random_input(&mut rng, 3, 2);
    let r1 = prepared.execute(&x1).unwrap();
    assert!(r1.weights_cached(), "prepare() packed every weight matrix");
    let x2 = model.random_input(&mut rng, 5, 2);
    let r2 = prepared.execute(&x2).unwrap();
    assert!(r2.weights_cached());
    assert!(
        s.cache_stats().hits > hits0,
        "weight-stationary serving hits the packing cache"
    );
}

#[test]
fn input_and_config_errors_are_typed() {
    let s = session();
    let model = QnnAttn::random(17, SPEC, 3, AttnWeightBits::default());
    let prepared = s.attn(&model).prepare().unwrap();
    // Wrong width.
    let e = prepared.execute(&IntMatrix::zeros(2, SPEC.d_model + 1)).err();
    assert!(matches!(e, Some(BismoError::ShapeMismatch(_))), "{e:?}");
    // Too many tokens.
    let e = prepared
        .execute(&IntMatrix::zeros(SPEC.max_seq + 1, SPEC.d_model))
        .err();
    assert!(matches!(e, Some(BismoError::ShapeMismatch(_))), "{e:?}");
    // Empty sequence.
    let e = prepared.execute(&IntMatrix::zeros(0, SPEC.d_model)).err();
    assert!(matches!(e, Some(BismoError::ShapeMismatch(_))), "{e:?}");
    // Entries outside the calibrated activation range.
    let hot = IntMatrix::from_fn(2, SPEC.d_model, |_, _| 9);
    let e = prepared.execute(&hot).err();
    assert!(matches!(e, Some(BismoError::PrecisionUnsupported(_))), "{e:?}");
    // Preparing with weight-side caching disabled is contradictory.
    let r = s.attn(&model).cache_rhs(false).prepare();
    assert!(
        matches!(r.err(), Some(BismoError::InvalidConfig(_))),
        "cache_rhs(false) + prepare() is rejected"
    );
}
