//! End-to-end tests of the network serving front door: real TCP
//! sockets against a [`NetServer`], proving bit-exactness, tenant
//! isolation, typed load shedding, prepared-weight replay and frame
//! robustness under garbage input.

use bismo::api::{BismoError, ExecOpts};
use bismo::arch::BismoConfig;
use bismo::bitmatrix::IntMatrix;
use bismo::coordinator::{Backend, Precision};
use bismo::lowering::{conv2d_direct, ConvSpec, LoweringMode, Tensor};
use bismo::net::{NetClient, NetServer, ServeConfig};
use bismo::util::Rng;
use std::io::{Read, Write};
use std::net::TcpStream;

fn small_server(cfg_mut: impl FnOnce(&mut ServeConfig)) -> NetServer {
    let mut cfg = ServeConfig::default();
    cfg.session.overlay = BismoConfig::small();
    cfg.session.workers = 2;
    cfg_mut(&mut cfg);
    NetServer::bind("127.0.0.1:0", cfg).unwrap()
}

#[test]
fn remote_matmul_is_bit_exact_on_both_backends() {
    let server = small_server(|_| {});
    let addr = server.local_addr();
    let mut cli = NetClient::connect(addr, "exactness").unwrap();
    let mut rng = Rng::new(0x7C9);
    for (i, backend) in [Backend::Engine, Backend::Sim, Backend::Engine, Backend::Sim]
        .into_iter()
        .enumerate()
    {
        let k = rng.index(200) + 1;
        let prec = Precision {
            wbits: rng.index(3) as u32 + 1,
            abits: rng.index(3) as u32 + 1,
            lsigned: true,
            rsigned: false,
        };
        let a = IntMatrix::random(&mut rng, 3 + i, k, prec.wbits, true);
        let b = IntMatrix::random(&mut rng, k, 4, prec.abits, false);
        let r = cli.matmul(&a, &b, prec, backend, true).unwrap();
        assert_eq!(r.result, a.matmul(&b), "case {i} vs i64 oracle");
        assert!(r.shards >= 1);
    }
    assert_eq!(server.served_total(), 4);
    assert_eq!(server.shed_total(), 0);
}

#[test]
fn tenants_cannot_hit_each_others_cached_weights() {
    let server = small_server(|_| {});
    let addr = server.local_addr();
    let mut alice = NetClient::connect(addr, "alice").unwrap();
    let mut bob = NetClient::connect(addr, "bob").unwrap();
    assert_ne!(alice.namespace(), bob.namespace());

    let mut rng = Rng::new(0x15_01A7E);
    let prec = Precision::unsigned(2, 3);
    let w = IntMatrix::random(&mut rng, 96, 4, 3, false);
    let a1 = IntMatrix::random(&mut rng, 2, 96, 2, false);
    let a2 = IntMatrix::random(&mut rng, 2, 96, 2, false);

    // Alice warms her namespace, then hits on the second call.
    let first = alice.matmul(&a1, &w, prec, Backend::Engine, false).unwrap();
    assert!(!first.rhs_cached, "first sight of these weights");
    let again = alice.matmul(&a2, &w, prec, Backend::Engine, false).unwrap();
    assert!(again.rhs_cached, "alice's second call hits her entry");

    let misses_before = alice.stats().unwrap().cache_misses;
    // Bob sends bit-identical weights: a shared-content cache would
    // hit; the namespaced cache must miss and repack.
    let bobs = bob.matmul(&a1, &w, prec, Backend::Engine, false).unwrap();
    assert!(!bobs.rhs_cached, "bob cannot reuse alice's packing");
    let misses_after = bob.stats().unwrap().cache_misses;
    assert!(
        misses_after > misses_before,
        "bob's identical weights were a real cache miss ({misses_before} -> {misses_after})"
    );
    assert_eq!(bobs.result, a1.matmul(&w), "isolation does not cost correctness");

    // A reconnect under the same name resolves to the same namespace,
    // so alice's cache entries outlive her connection.
    drop(alice);
    let mut alice2 = NetClient::connect(addr, "alice").unwrap();
    assert_eq!(alice2.namespace(), 1);
    let back = alice2.matmul(&a1, &w, prec, Backend::Engine, false).unwrap();
    assert!(back.rhs_cached, "same tenant name, same namespace, warm cache");
}

#[test]
fn prepared_weights_replay_and_stay_private() {
    let server = small_server(|_| {});
    let addr = server.local_addr();
    let mut alice = NetClient::connect(addr, "alice").unwrap();
    let mut bob = NetClient::connect(addr, "bob").unwrap();

    let mut rng = Rng::new(0xBEEF);
    let w = IntMatrix::random(&mut rng, 128, 5, 3, true);
    let a = IntMatrix::random(&mut rng, 4, 128, 2, false);
    let prec = Precision {
        wbits: 2,
        abits: 3,
        lsigned: false,
        rsigned: true,
    };

    let prepared = alice.prepare_weights(&w, 3, true).unwrap();
    let r = alice
        .matmul_prepared(prepared, &a, prec, Backend::Engine, true)
        .unwrap();
    assert_eq!(r.result, a.matmul(&w));
    assert!(r.rhs_cached, "prepared weights are resident at replay");

    // Bob guessing alice's weight id must look exactly like a missing
    // id — no cross-tenant probing.
    let stolen = bob.matmul_prepared(prepared, &a, prec, Backend::Engine, false);
    assert!(
        matches!(stolen, Err(BismoError::InvalidConfig(_))),
        "foreign weight id must be rejected, got {stolen:?}"
    );

    // A precision mismatch against the upload is typed, not silent.
    let bad = alice.matmul_prepared(
        prepared,
        &a,
        Precision {
            wbits: 2,
            abits: 2,
            lsigned: false,
            rsigned: true,
        },
        Backend::Engine,
        false,
    );
    assert!(matches!(bad, Err(BismoError::PrecisionUnsupported(_))));
}

#[test]
fn weight_quota_is_enforced_per_tenant() {
    // ~10 KiB quota: the first small upload fits, the second overflows.
    let server = small_server(|cfg| cfg.tenant_max_weight_bytes = 10 << 10);
    let addr = server.local_addr();
    let mut cli = NetClient::connect(addr, "hoarder").unwrap();
    let mut rng = Rng::new(3);
    let w = IntMatrix::random(&mut rng, 128, 8, 2, false); // 8 KiB dense
    cli.prepare_weights(&w, 2, false).unwrap();
    let over = cli.prepare_weights(&w, 2, false);
    assert!(
        matches!(over, Err(BismoError::CapacityExceeded(_))),
        "quota overflow must be typed, got {over:?}"
    );
    // Another tenant's quota is untouched.
    let mut other = NetClient::connect(addr, "frugal").unwrap();
    other.prepare_weights(&w, 2, false).unwrap();
}

#[test]
fn saturated_admission_queue_sheds_with_typed_overloaded() {
    // One admission slot total; several clients race closed-loop. The
    // losers must get typed Overloaded with a backoff hint — never a
    // hang, a panic or a dropped connection.
    let server = small_server(|cfg| {
        cfg.max_in_flight = 1;
        cfg.tenant_max_in_flight = 1;
    });
    let addr = server.local_addr();
    let shed_seen = std::sync::atomic::AtomicU64::new(0);
    std::thread::scope(|scope| {
        for t in 0..4u64 {
            let shed_seen = &shed_seen;
            scope.spawn(move || {
                let mut cli = NetClient::connect(addr, &format!("t{t}")).unwrap();
                let mut rng = Rng::new(t);
                let a = IntMatrix::random(&mut rng, 8, 256, 2, false);
                let b = IntMatrix::random(&mut rng, 256, 8, 2, false);
                let prec = Precision::unsigned(2, 2);
                let mut done = 0;
                while done < 3 {
                    // The sim backend is slow enough to hold the slot.
                    match cli.matmul(&a, &b, prec, Backend::Sim, false) {
                        Ok(r) => {
                            assert_eq!(r.result, a.matmul(&b));
                            done += 1;
                        }
                        Err(BismoError::Overloaded { retry_after_ms }) => {
                            assert!(retry_after_ms > 0, "hint must be actionable");
                            shed_seen.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            std::thread::sleep(std::time::Duration::from_millis(
                                retry_after_ms.min(10),
                            ));
                        }
                        Err(e) => panic!("unexpected error under saturation: {e}"),
                    }
                }
            });
        }
    });
    let shed = shed_seen.load(std::sync::atomic::Ordering::Relaxed);
    assert!(
        shed > 0,
        "4 clients racing 1 slot must shed at least once (got {shed})"
    );
    assert_eq!(server.shed_total(), shed, "server counted every shed");
    assert_eq!(server.served_total(), 12, "every request eventually served");
}

#[test]
fn corrupt_frames_never_take_the_server_down() {
    let server = small_server(|_| {});
    let addr = server.local_addr();

    // A volley of hostile byte streams straight at the socket.
    let payloads: Vec<Vec<u8>> = vec![
        b"GET / HTTP/1.1\r\n\r\n".to_vec(),
        vec![0u8; 64],
        vec![0xFF; 256],
        // Valid magic+version, absurd declared length.
        {
            let mut v = 0x4F4D_5342u32.to_le_bytes().to_vec();
            v.extend(1u16.to_le_bytes());
            v.extend(0x02u16.to_le_bytes());
            v.extend(7u32.to_le_bytes());
            v.extend(u32::MAX.to_le_bytes());
            v
        },
        // Valid header, truncated payload then EOF.
        {
            let mut v = 0x4F4D_5342u32.to_le_bytes().to_vec();
            v.extend(1u16.to_le_bytes());
            v.extend(0x02u16.to_le_bytes());
            v.extend(8u32.to_le_bytes());
            v.extend(1024u32.to_le_bytes());
            v.extend([0xAB; 10]);
            v
        },
    ];
    for (i, p) in payloads.iter().enumerate() {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(p).unwrap();
        let _ = s.flush();
        // The server either answers an error frame or closes; it must
        // never hang us forever.
        s.set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        let mut buf = [0u8; 256];
        let _ = s.read(&mut buf); // Err/0 both fine — just not a hang.
        drop(s);
        // After every corpse the server still serves real clients.
        let mut cli = NetClient::connect(addr, "survivor").unwrap();
        let mut rng = Rng::new(i as u64);
        let a = IntMatrix::random(&mut rng, 2, 64, 2, false);
        let b = IntMatrix::random(&mut rng, 64, 2, 2, false);
        let r = cli
            .matmul(&a, &b, Precision::unsigned(2, 2), Backend::Engine, false)
            .unwrap();
        assert_eq!(r.result, a.matmul(&b), "server healthy after corpse {i}");
    }
}

#[test]
fn work_before_hello_is_rejected_typed() {
    use bismo::net::wire::{self, Message, Request};
    let server = small_server(|_| {});
    let mut s = TcpStream::connect(server.local_addr()).unwrap();
    // Hand-roll a matmul request with no Hello first; the server must
    // answer a typed error frame, not execute it or hang.
    let mut rng = Rng::new(4);
    let a = IntMatrix::random(&mut rng, 2, 32, 1, false);
    let b = IntMatrix::random(&mut rng, 32, 2, 1, false);
    let raw = wire::encode_request(
        9,
        &Request::Matmul {
            prec: Precision::unsigned(1, 1),
            backend: Backend::Engine,
            verify: false,
            a,
            b,
        },
    )
    .unwrap();
    s.write_all(&raw).unwrap();
    s.flush().unwrap();
    let mut hdr = [0u8; wire::HEADER_BYTES];
    s.read_exact(&mut hdr).unwrap();
    let header = wire::decode_header(&hdr).unwrap();
    assert_eq!(header.req_id, 9, "error frame echoes the request id");
    let mut payload = vec![0u8; header.len];
    s.read_exact(&mut payload).unwrap();
    let resp = match wire::decode_payload(header.kind, &payload).unwrap() {
        Message::Response(r) => r,
        Message::Request(_) => panic!("server sent a request frame"),
    };
    let err = resp.to_error().expect("must be an error frame");
    assert!(
        matches!(err, BismoError::IllegalProgram(_)),
        "work before Hello must be IllegalProgram, got {err:?}"
    );
}

#[test]
fn conv_over_the_wire_matches_direct_convolution() {
    let server = small_server(|_| {});
    let mut cli = NetClient::connect(server.local_addr(), "convnet").unwrap();
    let mut rng = Rng::new(0xC0147);
    let spec = ConvSpec::simple(6, 6, 3, 4, 3, 1);
    let input = Tensor::random(&mut rng, 2, 6, 6, 3, 2, false);
    let weights = spec.weights_from_fn(|_, _, _, _| rng.operand(2, true));
    let prec = Precision {
        wbits: 2,
        abits: 2,
        lsigned: false,
        rsigned: true,
    };
    for (mode, gemms) in [(LoweringMode::Im2col, 1u32), (LoweringMode::Kn2row, 9u32)] {
        let r = cli
            .conv(
                spec,
                mode,
                &input,
                &weights,
                prec,
                &ExecOpts::new().backend(Backend::Engine).verify(true),
            )
            .unwrap();
        assert_eq!(r.gemms, gemms, "{mode:?} lowering shape");
        assert_eq!(
            r.output,
            conv2d_direct(&input, &weights, &spec),
            "{mode:?} over the wire vs direct oracle"
        );
    }
}

#[test]
fn graceful_shutdown_drains_and_refuses_new_connections() {
    let mut server = small_server(|_| {});
    let addr = server.local_addr();
    let mut cli = NetClient::connect(addr, "drainee").unwrap();
    let mut rng = Rng::new(9);
    let a = IntMatrix::random(&mut rng, 2, 64, 2, false);
    let b = IntMatrix::random(&mut rng, 64, 2, 2, false);
    cli.matmul(&a, &b, Precision::unsigned(2, 2), Backend::Engine, false)
        .unwrap();
    server.shutdown();
    // Post-drain the port no longer accepts (the listener is gone), or
    // an accepted-then-dropped connection errors out immediately.
    let late = NetClient::connect(addr, "late");
    assert!(late.is_err() || {
        let mut c = late.unwrap();
        c.stats().is_err()
    });
}
