//! Bounded fuzz smoke: all three structured-fuzz modes must come back
//! clean at modest iteration counts. CI runs the bigger sweep via
//! `bismo fuzz --iters 200 --seed 42`; this keeps the property wired
//! into plain `cargo test` as well.

use bismo::fuzz::{case_seed, fuzz_differential, fuzz_legal, fuzz_mutation};

#[test]
fn legal_programs_run_clean() {
    let out = fuzz_legal(25, 42);
    assert_eq!(out.mode, "legal");
    assert!(
        out.ok(),
        "legal-mode fuzz failures (replay with the listed seeds): {:?}",
        out.failures
    );
}

#[test]
fn mutated_programs_always_fail_typed() {
    let out = fuzz_mutation(50, 42);
    assert!(
        out.ok(),
        "mutation-mode fuzz failures (replay with the listed seeds): {:?}",
        out.failures
    );
}

#[test]
fn backends_agree_on_random_jobs() {
    let out = fuzz_differential(6, 42);
    assert!(
        out.ok(),
        "differential-mode fuzz failures (replay with the listed seeds): {:?}",
        out.failures
    );
}

#[test]
fn failure_seeds_are_replayable_handles() {
    // The seed printed for case i is exactly what the fuzzer derives
    // internally — a failure line is sufficient to reproduce.
    assert_eq!(case_seed(42, 17), case_seed(42, 17));
    assert_ne!(case_seed(42, 17), case_seed(42, 18));
}
