//! Property sweep: the tiled, plane-fused kernel engine is bit-exact
//! against the `gemm_bitserial` oracle (and the i64 reference) across
//! mixed precisions, signedness, sparse (zero-plane) operands and
//! ragged shapes — and the pooled batch runner preserves ordering and
//! per-job results.

use bismo::arch::BismoConfig;
use bismo::baseline::{gemm_bitserial, gemm_bitserial_parallel};
use bismo::bitmatrix::{BitSerialMatrix, IntMatrix};
use bismo::coordinator::{BismoBatchRunner, BismoContext, MatmulOptions, Precision};
use bismo::kernel::{gemm_tiled, gemm_tiled_tier, gemm_tiled_with, KernelConfig, WorkerPool};
use bismo::simd::DispatchTier;
use bismo::util::{property_sweep, Rng};

/// Random matrix with controllable plane sparsity: `mode 0` = dense,
/// `mode 1` = even values (LSB plane all-zero), `mode 2` = tiny values
/// (high planes all-zero), `mode 3` = all-zero operand.
fn sparse_random(rng: &mut Rng, rows: usize, cols: usize, bits: u32, signed: bool, mode: usize) -> IntMatrix {
    let m = IntMatrix::random(rng, rows, cols, bits, signed);
    let (lo, hi) = if signed {
        (-(1i64 << (bits - 1)), (1i64 << (bits - 1)) - 1)
    } else {
        (0, (1i64 << bits) - 1)
    };
    match mode {
        1 => IntMatrix::from_fn(rows, cols, |r, c| ((m.get(r, c).abs() / 2) * 2).clamp(lo, hi)),
        2 => IntMatrix::from_fn(rows, cols, |r, c| (m.get(r, c).abs() % 2).clamp(lo, hi)),
        3 => IntMatrix::zeros(rows, cols),
        _ => m,
    }
}

#[test]
fn tiled_engine_matches_oracle_everywhere() {
    property_sweep(0xB17_5E81, 60, |rng, case| {
        let m = rng.index(33) + 1;
        let k = rng.index(300) + 1; // usually not a multiple of 64
        let n = rng.index(33) + 1;
        let wbits = rng.index(8) as u32 + 1;
        let abits = rng.index(8) as u32 + 1;
        let lsigned = rng.chance(0.5);
        let rsigned = rng.chance(0.5);
        let lmode = rng.index(4);
        let rmode = rng.index(3); // keep RHS nonzero a bit more often
        let a = sparse_random(rng, m, k, wbits, lsigned, lmode);
        let b = sparse_random(rng, k, n, abits, rsigned, rmode);
        let expect = a.matmul(&b);

        let la = BitSerialMatrix::from_int(&a, wbits, lsigned);
        let rb = BitSerialMatrix::from_int_transposed(&b, abits, rsigned);
        let oracle = gemm_bitserial(&la, &rb);
        assert_eq!(oracle, expect, "oracle vs reference, case {case}");

        let tiled = gemm_tiled(&la, &rb).unwrap();
        assert_eq!(
            tiled, oracle,
            "case {case}: m={m} k={k} n={n} w={wbits} a={abits} \
             ls={lsigned} rs={rsigned} lmode={lmode} rmode={rmode}"
        );
    });
}

#[test]
fn tiled_engine_matches_oracle_on_every_dispatch_tier() {
    // The same oracle property as above, re-run at every SIMD tier the
    // host supports (forced dispatch, sparse planes included) — the
    // engine half of the forced-dispatch test matrix.
    let tiers = DispatchTier::supported();
    property_sweep(0xB17_51D0, 25, |rng, case| {
        let m = rng.index(17) + 1;
        let k = rng.index(300) + 1;
        let n = rng.index(17) + 1;
        let wbits = rng.index(8) as u32 + 1;
        let abits = rng.index(8) as u32 + 1;
        let lsigned = rng.chance(0.5);
        let rsigned = rng.chance(0.5);
        let lmode = rng.index(4);
        let a = sparse_random(rng, m, k, wbits, lsigned, lmode);
        let b = sparse_random(rng, k, n, abits, rsigned, rng.index(3));
        let expect = a.matmul(&b);
        let rb = BitSerialMatrix::from_int_transposed(&b, abits, rsigned);
        for &tier in &tiers {
            let la = BitSerialMatrix::from_int_tier(&a, wbits, lsigned, tier);
            assert_eq!(
                gemm_tiled_tier(&la, &rb, tier).unwrap(),
                expect,
                "case {case}: tier={tier} m={m} k={k} n={n} w={wbits} a={abits} lmode={lmode}"
            );
        }
    });
}

#[test]
fn tiled_engine_handles_ragged_tiles() {
    // m, n, k straddling every tile boundary for several geometries.
    let mut rng = Rng::new(0x4A66);
    for (m, k, n) in [(1, 64, 1), (7, 65, 9), (8, 63, 8), (15, 128, 17), (33, 191, 31)] {
        let a = IntMatrix::random(&mut rng, m, k, 4, true);
        let b = IntMatrix::random(&mut rng, k, n, 3, false);
        let la = BitSerialMatrix::from_int(&a, 4, true);
        let rb = BitSerialMatrix::from_int_transposed(&b, 3, false);
        let expect = a.matmul(&b);
        for (tm, tn) in [(1, 1), (2, 7), (8, 8), (64, 64)] {
            for tk in [64, 128, usize::MAX] {
                let cfg = KernelConfig {
                    tile_m: tm,
                    tile_n: tn,
                    tile_k: tk,
                };
                assert_eq!(
                    gemm_tiled_with(&la, &rb, &cfg, None).unwrap(),
                    expect,
                    "m={m} k={k} n={n} tile {tm}x{tn}x{tk}"
                );
            }
        }
    }
}

#[test]
fn parallel_paths_match_serial_on_shared_pool() {
    property_sweep(0x600D, 10, |rng, _| {
        let m = rng.index(50) + 1;
        let k = rng.index(400) + 1;
        let n = rng.index(20) + 1;
        let a = IntMatrix::random(rng, m, k, 3, true);
        let b = IntMatrix::random(rng, k, n, 3, true);
        let la = BitSerialMatrix::from_int(&a, 3, true);
        let rb = BitSerialMatrix::from_int_transposed(&b, 3, true);
        let serial = gemm_bitserial(&la, &rb);
        let cfg = KernelConfig::default();
        for threads in [1, 2, 3, 8] {
            assert_eq!(gemm_bitserial_parallel(&la, &rb, threads), serial);
            assert_eq!(
                gemm_tiled_with(&la, &rb, &cfg, Some((WorkerPool::global(), threads))).unwrap(),
                serial
            );
        }
    });
}

#[test]
fn dedicated_pool_usable_alongside_global() {
    let pool = WorkerPool::new(3);
    let mut rng = Rng::new(0xD0_01);
    let a = IntMatrix::random(&mut rng, 20, 130, 2, false);
    let b = IntMatrix::random(&mut rng, 130, 12, 2, false);
    let la = BitSerialMatrix::from_int(&a, 2, false);
    let rb = BitSerialMatrix::from_int_transposed(&b, 2, false);
    let expect = a.matmul(&b);
    let cfg = KernelConfig::default();
    for _ in 0..5 {
        assert_eq!(gemm_tiled_with(&la, &rb, &cfg, Some((&pool, 3))).unwrap(), expect);
        assert_eq!(
            gemm_tiled_with(&la, &rb, &cfg, Some((WorkerPool::global(), 2))).unwrap(),
            expect
        );
    }
}

#[test]
fn batch_runner_preserves_order_and_matches_per_job_results() {
    let runner = BismoBatchRunner::new(BismoConfig::small(), 4).unwrap();
    let serial = BismoContext::new(BismoConfig::small()).unwrap();
    let mut rng = Rng::new(0xBA7C);
    let jobs: Vec<_> = (0..12)
        .map(|j| {
            let k = rng.index(256) + 1;
            let m = rng.index(8) + 1;
            let n = rng.index(8) + 1;
            let a = IntMatrix::random(&mut rng, m, k, 2, false);
            let b = IntMatrix::random(&mut rng, k, n, 2, false);
            let opts = MatmulOptions {
                bit_skip: j % 2 == 0,
                ..Default::default()
            };
            (a, b, Precision::unsigned(2, 2), opts)
        })
        .collect();
    // Two batches on the same runner: pooled workers are reused, and
    // each outcome lands at its job's index with identical results to
    // a serial single-context run.
    for _ in 0..2 {
        let outcomes = runner.run_batch(&jobs);
        assert_eq!(outcomes.len(), jobs.len());
        for (i, o) in outcomes.iter().enumerate() {
            assert_eq!(o.index, i, "outcome {i} out of order");
            let (p, rep) = o.result.as_ref().unwrap();
            let (sp, srep) = serial
                .matmul(&jobs[i].0, &jobs[i].1, jobs[i].2, jobs[i].3)
                .unwrap();
            assert_eq!(*p, sp, "job {i} result");
            assert_eq!(rep.cycles, srep.cycles, "job {i} report");
            assert_eq!(*p, jobs[i].0.matmul(&jobs[i].1), "job {i} reference");
        }
    }
}
