//! Failure injection: broken schedules and abusive configurations must
//! be *diagnosed*, not silently mis-simulated.

use bismo::api::BismoError;
use bismo::arch::{BismoConfig, PYNQ_Z1};
use bismo::bitmatrix::dram::DramImage;
use bismo::isa::{ExecuteRun, FetchRun, Instr, Program, ResultRun, Stage, SyncChannel};
use bismo::sim::{SimError, Simulation};

fn cfg() -> BismoConfig {
    BismoConfig::small()
}

fn sim() -> Simulation {
    Simulation::new(cfg(), &PYNQ_Z1, DramImage::new(4096)).unwrap()
}

fn exec(chunks: u32, commit: bool) -> Instr {
    Instr::Execute(ExecuteRun {
        lhs_offset: 0,
        rhs_offset: 0,
        num_chunks: chunks,
        shift: 0,
        negate: false,
        acc_reset: true,
        commit_result: commit,
    })
}

#[test]
fn wait_without_signal_deadlocks_with_diagnosis() {
    let mut p = Program::new();
    p.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
    p.push(Stage::Fetch, Instr::Wait(SyncChannel::ExecuteToFetch));
    p.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
    p.push(Stage::Execute, Instr::Signal(SyncChannel::ExecuteToFetch));
    match sim().run(&p) {
        Err(BismoError::SimFault(SimError::Deadlock { blocked })) => {
            let msg = format!("{blocked:?}");
            assert!(msg.contains("fetch") && msg.contains("execute"), "{msg}");
            assert!(msg.contains("waiting on"), "{msg}");
        }
        other => panic!("expected deadlock, got {other:?}"),
    }
}

#[test]
fn result_buffer_overflow_detected() {
    // B_r = 2: three commits without any drain must fault on the third.
    let mut p = Program::new();
    for _ in 0..3 {
        p.push(Stage::Execute, exec(1, true));
        p.push(Stage::Execute, Instr::Signal(SyncChannel::ExecuteToResult));
    }
    for _ in 0..3 {
        p.push(Stage::Result, Instr::Wait(SyncChannel::ExecuteToResult));
        p.push(
            Stage::Result,
            Instr::Result(ResultRun {
                dram_base: 0,
                offset: 0,
                rows: 1,
                cols: 1,
                row_stride_bytes: 4,
            }),
        );
    }
    // Force the engine to run all execute instructions before result
    // (fetch->execute->result priority does this already).
    match sim().run(&p) {
        Err(BismoError::SimFault(SimError::Fault { stage, msg, .. })) => {
            assert_eq!(stage, "execute");
            assert!(msg.contains("overflow"), "{msg}");
        }
        other => panic!("expected overflow fault, got {other:?}"),
    }
}

#[test]
fn fetch_out_of_buffer_range_detected() {
    let mut p = Program::new();
    p.push(
        Stage::Fetch,
        Instr::Fetch(FetchRun {
            dram_base: 0,
            block_bytes: 8,
            block_stride_bytes: 0,
            num_blocks: 1,
            buf_offset: 0,
            buf_start: 60, // far out of range (4 buffers exist)
            buf_range: 1,
            words_per_buf: 1,
        }),
    );
    match sim().run(&p) {
        Err(BismoError::SimFault(SimError::Fault { stage, msg, .. })) => {
            assert_eq!(stage, "fetch");
            assert!(msg.contains("out of range"), "{msg}");
        }
        other => panic!("expected fetch fault, got {other:?}"),
    }
}

#[test]
fn execute_past_buffer_depth_detected() {
    let mut p = Program::new();
    p.push(Stage::Execute, exec(5000, false)); // bm = 1024
    match sim().run(&p) {
        Err(BismoError::SimFault(SimError::Fault { stage, .. })) => {
            assert_eq!(stage, "execute")
        }
        other => panic!("expected execute fault, got {other:?}"),
    }
}

#[test]
fn illegal_queue_placement_rejected() {
    let mut p = Program::new();
    p.push(Stage::Result, exec(1, false)); // RunExecute in result queue
    // Program validation surfaces the structured IllegalProgram variant
    // directly — no stringly-typed sim error wrapping it.
    match sim().run(&p) {
        Err(BismoError::IllegalProgram(msg)) => assert!(msg.contains("result queue"), "{msg}"),
        other => panic!("expected IllegalProgram, got {other:?}"),
    }
}

#[test]
fn accumulator_overflow_counted_not_fatal() {
    // A=8 bits with dense data overflows; the simulator must complete
    // and report the wraps (like the hardware register would wrap).
    let c = BismoConfig {
        acc_bits: 8,
        ..cfg()
    };
    let mut dram = DramImage::new(4096);
    for i in 0..64 {
        dram.write_u64(i * 8, u64::MAX);
    }
    let mut p = Program::new();
    p.push(
        Stage::Fetch,
        Instr::Fetch(FetchRun {
            dram_base: 0,
            block_bytes: 64,
            block_stride_bytes: 0,
            num_blocks: 4,
            buf_offset: 0,
            buf_start: 0,
            buf_range: 4,
            words_per_buf: 8,
        }),
    );
    p.push(Stage::Fetch, Instr::Signal(SyncChannel::FetchToExecute));
    p.push(Stage::Execute, Instr::Wait(SyncChannel::FetchToExecute));
    p.push(Stage::Execute, exec(8, false)); // 8 chunks of all-ones: 512 >> 8-bit range
    let mut s = Simulation::new(c, &PYNQ_Z1, dram).unwrap();
    let stats = s.run(&p).unwrap();
    assert!(stats.acc_overflows > 0, "overflow must be counted");
}

#[test]
fn bad_config_rejected_before_running() {
    let bad = BismoConfig {
        dk: 48,
        ..cfg()
    };
    match Simulation::new(bad, &PYNQ_Z1, DramImage::new(64)) {
        Err(BismoError::InvalidConfig(msg)) => assert!(msg.contains("power of two"), "{msg}"),
        other => panic!("expected InvalidConfig, got {:?}", other.err()),
    }
}

#[test]
fn budget_exhaustion_is_typed_through_the_service() {
    // A sim-backend request with an absurdly small instruction budget
    // must fail with a typed BudgetExceeded, not hang or panic.
    use bismo::bitmatrix::IntMatrix;
    use bismo::coordinator::{
        Backend, BismoService, GemmRequest, Precision, RequestOptions, ServiceConfig,
    };
    use bismo::util::Rng;

    let svc = BismoService::new(ServiceConfig {
        workers: 2,
        ..ServiceConfig::default()
    })
    .unwrap();
    let mut rng = Rng::new(11);
    let a = IntMatrix::random(&mut rng, 4, 64, 2, false);
    let b = IntMatrix::random(&mut rng, 64, 4, 2, false);
    let opts = RequestOptions {
        backend: Backend::Sim,
        max_instrs: Some(1),
        ..RequestOptions::default()
    };
    let r = svc
        .submit(GemmRequest::with_opts(a, b, Precision::unsigned(2, 2), opts))
        .wait();
    match r {
        Err(BismoError::SimFault(SimError::BudgetExceeded { budget: 1 })) => {}
        other => panic!("expected BudgetExceeded {{1}}, got {other:?}"),
    }
    svc.shutdown();
}

#[test]
fn mid_batch_fault_poisons_only_the_offending_request() {
    // One poisoned request (budget watchdog trips mid-simulation) rides
    // in the same worker pool as concurrent well-formed requests on
    // both backends; the healthy requests must complete bit-exactly.
    use bismo::bitmatrix::IntMatrix;
    use bismo::coordinator::{
        Backend, BismoService, GemmRequest, Precision, RequestOptions, ServiceConfig,
    };
    use bismo::util::Rng;

    let svc = BismoService::new(ServiceConfig {
        workers: 3,
        ..ServiceConfig::default()
    })
    .unwrap();
    let mut rng = Rng::new(23);
    let prec = Precision::unsigned(2, 2);

    let poisoned = {
        let a = IntMatrix::random(&mut rng, 4, 64, 2, false);
        let b = IntMatrix::random(&mut rng, 64, 4, 2, false);
        let opts = RequestOptions {
            backend: Backend::Sim,
            max_instrs: Some(2),
            ..RequestOptions::default()
        };
        svc.submit(GemmRequest::with_opts(a, b, prec, opts))
    };
    let healthy: Vec<_> = (0..6)
        .map(|i| {
            let a = IntMatrix::random(&mut rng, 4, 64, 2, false);
            let b = IntMatrix::random(&mut rng, 64, 4, 2, false);
            let expect = a.matmul(&b);
            let opts = RequestOptions {
                backend: if i % 2 == 0 {
                    Backend::Engine
                } else {
                    Backend::Sim
                },
                ..RequestOptions::default()
            };
            let h = svc.submit(GemmRequest::with_opts(a, b, prec, opts));
            (h, expect)
        })
        .collect();

    match poisoned.wait() {
        Err(BismoError::SimFault(SimError::BudgetExceeded { .. })) => {}
        other => panic!("poisoned request: expected BudgetExceeded, got {other:?}"),
    }
    for (h, expect) in healthy {
        let resp = h.wait().expect("healthy request must complete");
        assert_eq!(resp.result, expect, "healthy request result corrupted");
    }
    svc.shutdown();
}

#[test]
fn error_display_is_informative() {
    let e = SimError::Fault {
        stage: "fetch",
        pc: 3,
        msg: "boom".into(),
    };
    let s = format!("{e}");
    assert!(s.contains("fetch") && s.contains('3') && s.contains("boom"));
    let d = SimError::Deadlock {
        blocked: vec![("execute", 1, "waiting on fetch->execute".into())],
    };
    assert!(format!("{d}").contains("deadlock"));
}
