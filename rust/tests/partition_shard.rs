//! Sharded bit-exactness: for random shapes, precisions and signs, any
//! shard split of a GEMM — row blocks, column blocks, both axes, and
//! (on the engine) bit-plane groups — merges to exactly the
//! `gemm_bitserial` oracle, on both execution backends.

use bismo::api::{Backend, BismoError, Session, SessionConfig};
use bismo::baseline::gemm_bitserial;
use bismo::bitmatrix::{BitSerialMatrix, IntMatrix};
use bismo::coordinator::Precision;
use bismo::kernel::{gemm_tiled_block, gemm_tiled_block_tier, KernelConfig};
use bismo::partition::ShardPlan;
use bismo::simd::DispatchTier;
use bismo::util::{property_sweep, Rng};

fn random_case(
    rng: &mut Rng,
    max_mn: usize,
    max_k: usize,
    max_bits: u32,
) -> (IntMatrix, IntMatrix, Precision, IntMatrix) {
    let m = rng.index(max_mn) + 1;
    let k = rng.index(max_k) + 1;
    let n = rng.index(max_mn) + 1;
    let prec = Precision {
        wbits: rng.index(max_bits as usize) as u32 + 1,
        abits: rng.index(max_bits as usize) as u32 + 1,
        lsigned: rng.chance(0.5),
        rsigned: rng.chance(0.5),
    };
    let a = IntMatrix::random(rng, m, k, prec.wbits, prec.lsigned);
    let b = IntMatrix::random(rng, k, n, prec.abits, prec.rsigned);
    // The CPU bit-serial oracle is the ground truth the sharded paths
    // must reproduce bit-exactly.
    let la = BitSerialMatrix::from_int(&a, prec.wbits, prec.lsigned);
    let rb = BitSerialMatrix::from_int_transposed(&b, prec.abits, prec.rsigned);
    let expect = gemm_bitserial(&la, &rb);
    assert_eq!(expect, a.matmul(&b), "oracle vs i64 reference");
    (a, b, prec, expect)
}

#[test]
fn engine_sharded_matches_oracle_for_any_grid() {
    let session = Session::with_defaults().unwrap();
    property_sweep(0x5AA2D, 10, |rng, case| {
        let (a, b, prec, expect) = random_case(rng, 20, 200, 6);
        for (rows, cols) in [
            (1, 1),
            (2, 1),
            (1, 3),
            (2, 2),
            (3, 2),
            (4, 4),
            (8, 1),
            (1, 8),
            (8, 8),
        ] {
            let resp = session
                .matmul(prec)
                .backend(Backend::Engine)
                .shard_grid(rows, cols)
                .run(a.clone(), b.clone())
                .unwrap();
            assert_eq!(
                resp.result, expect,
                "case {case}: {}×{}·{}×{} grid {rows}x{cols}",
                a.rows, a.cols, b.rows, b.cols
            );
        }
    });
}

#[test]
fn sim_sharded_matches_oracle_for_any_grid() {
    let session = Session::with_defaults().unwrap();
    property_sweep(0x51AA2D, 6, |rng, case| {
        // Smaller shapes: every shard is a full cycle-accurate run on
        // its own simulator instance.
        let (a, b, prec, expect) = random_case(rng, 10, 128, 3);
        for (rows, cols) in [(2, 1), (1, 2), (2, 2), (3, 3)] {
            let resp = session
                .matmul(prec)
                .backend(Backend::Sim)
                .shard_grid(rows, cols)
                .run(a.clone(), b.clone())
                .unwrap();
            assert_eq!(
                resp.result, expect,
                "case {case}: {}×{}·{}×{} grid {rows}x{cols}",
                a.rows, a.cols, b.rows, b.cols
            );
            if resp.shards > 1 {
                let rep = resp.report.expect("merged sim report");
                assert!(rep.cycles > 0, "case {case}");
            }
        }
    });
}

#[test]
fn instance_counts_1_through_8_stay_exact_on_both_backends() {
    let session = Session::with_defaults().unwrap();
    property_sweep(0x1458, 4, |rng, case| {
        let (a, b, prec, expect) = random_case(rng, 12, 100, 3);
        for backend in [Backend::Engine, Backend::Sim] {
            for shards in 1..=8usize {
                let resp = session
                    .matmul(prec)
                    .backend(backend)
                    .instances(shards)
                    .run(a.clone(), b.clone())
                    .unwrap();
                assert_eq!(
                    resp.result,
                    expect,
                    "case {case}: {} instances={shards}",
                    backend.name()
                );
                assert!(resp.shards >= 1 && resp.shards <= shards);
            }
        }
    });
}

#[test]
fn plane_group_shards_assemble_exactly() {
    // Bit-plane-group sharding is an engine-level capability: partial
    // products over plane subsets sum to the full product (GEMM is
    // linear in the bit-plane decomposition), including the negated
    // MSB plane of signed operands.
    property_sweep(0x91A7E, 8, |rng, case| {
        let m = rng.index(14) + 1;
        let k = rng.index(180) + 1;
        let n = rng.index(14) + 1;
        let wbits = rng.index(6) as u32 + 2;
        let abits = rng.index(4) as u32 + 1;
        let lsigned = rng.chance(0.5);
        let a = IntMatrix::random(rng, m, k, wbits, lsigned);
        let b = IntMatrix::random(rng, k, n, abits, true);
        let la = BitSerialMatrix::from_int(&a, wbits, lsigned);
        let rb = BitSerialMatrix::from_int_transposed(&b, abits, true);
        let expect = gemm_bitserial(&la, &rb);
        let grids = [(1, 1), (2, 2), (3, 1)];
        let (gr, gc) = grids[rng.index(grids.len())];
        for groups in 1..=wbits as usize {
            let plan = ShardPlan::grid(m, n, gr, gc).with_plane_groups(wbits, groups);
            let parts: Vec<IntMatrix> = plan
                .shards()
                .iter()
                .map(|s| {
                    gemm_tiled_block(
                        &la,
                        &rb,
                        s.rows.clone(),
                        s.cols.clone(),
                        s.planes.clone(),
                        &KernelConfig::default(),
                        None,
                    )
                    .unwrap()
                })
                .collect();
            assert_eq!(
                plan.assemble(&parts).unwrap(),
                expect,
                "case {case}: m={m} k={k} n={n} w={wbits} grid {gr}x{gc} groups={groups}"
            );
        }
    });
}

#[test]
fn sharded_blocks_assemble_exactly_on_every_dispatch_tier() {
    // Shard-level forced dispatch: run every block of a grid + plane
    // group split through gemm_tiled_block_tier at each supported SIMD
    // tier; reassembly must be bit-exact against the oracle on all of
    // them (mixing packing tier and strip tier is legal by the
    // word-identity contract).
    property_sweep(0x54A2D_71, 6, |rng, case| {
        let (a, b, prec, expect) = random_case(rng, 16, 180, 5);
        let la = BitSerialMatrix::from_int(&a, prec.wbits, prec.lsigned);
        let rb = BitSerialMatrix::from_int_transposed(&b, prec.abits, prec.rsigned);
        for tier in DispatchTier::supported() {
            let la_t = BitSerialMatrix::from_int_tier(&a, prec.wbits, prec.lsigned, tier);
            assert_eq!(la_t, la, "case {case}: tier={tier} packing differs");
            let plan = ShardPlan::grid(a.rows, b.cols, 2, 2).with_plane_groups(prec.wbits, 2);
            let parts: Vec<IntMatrix> = plan
                .shards()
                .iter()
                .map(|s| {
                    gemm_tiled_block_tier(
                        &la_t,
                        &rb,
                        s.rows.clone(),
                        s.cols.clone(),
                        s.planes.clone(),
                        &KernelConfig::default(),
                        None,
                        tier,
                    )
                    .unwrap()
                })
                .collect();
            assert_eq!(plan.assemble(&parts).unwrap(), expect, "case {case}: tier={tier}");
        }
    });
}

#[test]
fn sharded_execution_composes_with_cache_and_prepared_weights() {
    // The sharded path reads the same cached packings as the single
    // path: prepare weights once, then execute sharded — the RHS must
    // be served from the cache and the result stay exact.
    let session = Session::new(SessionConfig::default()).unwrap();
    let mut rng = Rng::new(0xCAC4E);
    let w = IntMatrix::random(&mut rng, 96, 16, 3, true);
    let prec = Precision {
        wbits: 2,
        abits: 3,
        lsigned: false,
        rsigned: true,
    };
    session.prepare(w.clone(), prec).unwrap();
    for shards in [2usize, 4] {
        let x = IntMatrix::random(&mut rng, 8, 96, 2, false);
        let resp = session
            .matmul(prec)
            .instances(shards)
            .run(x.clone(), w.clone())
            .unwrap();
        assert_eq!(resp.result, x.matmul(&w));
        assert!(resp.rhs_cached, "prepared packing served the sharded run");
        assert_eq!(resp.shards, shards);
    }
}

#[test]
fn sharded_errors_are_typed() {
    let session = Session::with_defaults().unwrap();
    // Degenerate grids fail before queueing.
    let r = session
        .matmul(Precision::unsigned(2, 2))
        .shard_grid(0, 1)
        .submit(IntMatrix::zeros(2, 2), IntMatrix::zeros(2, 2));
    assert!(matches!(r, Err(BismoError::InvalidConfig(_))));
    // An impossible auto-shard budget surfaces the cost model's
    // CapacityExceeded through the response path.
    let r = session
        .matmul(Precision::unsigned(2, 2))
        .auto_shard(bismo::api::ResourceBudget { luts: 10, brams: 1 })
        .run(IntMatrix::zeros(4, 4), IntMatrix::zeros(4, 4));
    assert!(matches!(r, Err(BismoError::CapacityExceeded(_))), "{r:?}");
}
