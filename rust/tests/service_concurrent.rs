//! Integration tests for the asynchronous serving layer: results must
//! be bit-exact against the CPU bit-serial oracle under concurrent
//! submission, across backends, and with the packing cache on or off.

use bismo::arch::BismoConfig;
use bismo::baseline::gemm_bitserial;
use bismo::bitmatrix::{BitSerialMatrix, IntMatrix};
use bismo::coordinator::{
    Backend, BismoService, GemmRequest, Precision, RequestOptions, ServiceConfig,
};
use bismo::util::{property_sweep, Rng};
use std::sync::Arc;

fn service(workers: usize, max_batch: usize, cache_bytes: usize) -> BismoService {
    BismoService::new(ServiceConfig {
        workers,
        max_batch,
        cache_bytes,
        overlay: BismoConfig::small(),
    })
    .unwrap()
}

/// Oracle product via the naive bit-serial reference.
fn oracle(a: &IntMatrix, b: &IntMatrix, prec: Precision) -> IntMatrix {
    let la = BitSerialMatrix::from_int(a, prec.wbits, prec.lsigned);
    let rb = BitSerialMatrix::from_int_transposed(b, prec.abits, prec.rsigned);
    gemm_bitserial(&la, &rb)
}

#[test]
fn concurrent_submitters_get_bit_exact_results() {
    // Several OS threads hammer one service concurrently; every result
    // must match both the i64 reference and the bit-serial oracle.
    let svc = service(4, 8, 32 << 20);
    std::thread::scope(|scope| {
        for t in 0..4u64 {
            let svc = &svc;
            scope.spawn(move || {
                let mut rng = Rng::new(0xC0 + t);
                for i in 0..6 {
                    let m = rng.index(8) + 1;
                    let k = rng.index(200) + 1;
                    let n = rng.index(8) + 1;
                    let w = rng.index(4) as u32 + 1;
                    let ab = rng.index(4) as u32 + 1;
                    let prec = Precision {
                        wbits: w,
                        abits: ab,
                        lsigned: true,
                        rsigned: false,
                    };
                    let a = IntMatrix::random(&mut rng, m, k, w, true);
                    let b = IntMatrix::random(&mut rng, k, n, ab, false);
                    let backend = if rng.chance(0.3) {
                        Backend::Sim
                    } else {
                        Backend::Engine
                    };
                    let opts = RequestOptions {
                        backend,
                        ..Default::default()
                    };
                    let expect = a.matmul(&b);
                    assert_eq!(expect, oracle(&a, &b, prec), "thread {t} job {i} oracle");
                    let resp = svc
                        .run(GemmRequest::with_opts(a, b, prec, opts))
                        .unwrap_or_else(|e| panic!("thread {t} job {i}: {e}"));
                    assert_eq!(resp.result, expect, "thread {t} job {i}");
                }
            });
        }
    });
    assert_eq!(svc.submitted(), 24);
    assert_eq!(svc.completed(), 24);
}

#[test]
fn backends_agree_with_each_other_and_the_oracle() {
    let svc = service(2, 4, 16 << 20);
    property_sweep(0x5E2C, 10, |rng, case| {
        let m = rng.index(10) + 1;
        let k = rng.index(180) + 1;
        let n = rng.index(10) + 1;
        let w = rng.index(3) as u32 + 1;
        let ab = rng.index(3) as u32 + 1;
        let (ls, rs) = (rng.chance(0.5), rng.chance(0.5));
        let prec = Precision {
            wbits: w,
            abits: ab,
            lsigned: ls,
            rsigned: rs,
        };
        let a = Arc::new(IntMatrix::random(rng, m, k, w, ls));
        let b = Arc::new(IntMatrix::random(rng, k, n, ab, rs));
        // Opt the LHS into the cache too: the same operands go to both
        // backends, exercising reuse on both sides.
        let engine = svc
            .run(GemmRequest::with_opts(
                a.clone(),
                b.clone(),
                prec,
                RequestOptions {
                    backend: Backend::Engine,
                    cache_lhs: true,
                    ..Default::default()
                },
            ))
            .unwrap();
        let sim = svc
            .run(GemmRequest::with_opts(
                a.clone(),
                b.clone(),
                prec,
                RequestOptions {
                    backend: Backend::Sim,
                    cache_lhs: true,
                    ..Default::default()
                },
            ))
            .unwrap();
        assert_eq!(engine.result, sim.result, "case {case}");
        assert_eq!(engine.result, oracle(&a, &b, prec), "case {case} oracle");
        assert!(engine.report.is_none());
        assert!(sim.report.is_some());
        // Same operands twice: the second request's packings are hits.
        assert!(sim.lhs_cached && sim.rhs_cached, "case {case} cache reuse");
    });
}

#[test]
fn cache_on_and_off_are_observationally_identical() {
    let with_cache = service(2, 4, 32 << 20);
    let without_cache = service(2, 4, 0);
    let mut rng = Rng::new(0x0FF);
    let w = Arc::new(IntMatrix::random(&mut rng, 130, 6, 4, true));
    let prec = Precision {
        wbits: 2,
        abits: 4,
        lsigned: false,
        rsigned: true,
    };
    for _ in 0..5 {
        let x = Arc::new(IntMatrix::random(&mut rng, 4, 130, 2, false));
        let on = with_cache
            .run(GemmRequest::new(x.clone(), w.clone(), prec))
            .unwrap();
        let off = without_cache
            .run(GemmRequest::new(x.clone(), w.clone(), prec))
            .unwrap();
        assert_eq!(on.result, off.result);
        assert!(!off.lhs_cached && !off.rhs_cached, "cache-off never hits");
    }
    assert_eq!(with_cache.cache_stats().hits, 4, "weight reused 4 times");
    assert_eq!(without_cache.cache_stats().hits, 0);
    assert_eq!(without_cache.cache_bytes(), 0);
}

#[test]
fn open_stream_of_async_submissions_preserves_request_identity() {
    // Fire a burst of async submissions (more than one micro-batch),
    // then collect out of order: each handle must carry exactly its
    // own request's product.
    let svc = service(3, 4, 16 << 20);
    let mut rng = Rng::new(0xA57);
    let jobs: Vec<(Arc<IntMatrix>, Arc<IntMatrix>)> = (0..20)
        .map(|_| {
            let k = rng.index(150) + 1;
            (
                Arc::new(IntMatrix::random(&mut rng, 3, k, 2, false)),
                Arc::new(IntMatrix::random(&mut rng, k, 4, 3, true)),
            )
        })
        .collect();
    let prec = Precision {
        wbits: 2,
        abits: 3,
        lsigned: false,
        rsigned: true,
    };
    let handles: Vec<_> = jobs
        .iter()
        .map(|(a, b)| svc.submit(GemmRequest::new(a.clone(), b.clone(), prec)))
        .collect();
    // Collect in reverse order to decouple completion from submission.
    for (h, (a, b)) in handles.into_iter().zip(&jobs).rev() {
        assert_eq!(h.wait().unwrap().result, a.matmul(b));
    }
}

#[test]
fn bit_skip_on_sim_backend_stays_exact_through_the_cache() {
    let svc = service(2, 4, 16 << 20);
    // Even-valued operand: the LSB plane is empty, bit-skip drops it.
    let a = IntMatrix::from_fn(4, 128, |r, c| (((r + c) % 4) as i64) * 2);
    let b = Arc::new(IntMatrix::from_fn(128, 4, |r, c| ((r * c) % 4) as i64));
    let prec = Precision {
        wbits: 3,
        abits: 2,
        lsigned: false,
        rsigned: false,
    };
    let expect = a.matmul(&b);
    for bit_skip in [false, true, true] {
        let opts = RequestOptions {
            backend: Backend::Sim,
            bit_skip,
            ..Default::default()
        };
        let resp = svc
            .run(GemmRequest::with_opts(a.clone(), b.clone(), prec, opts))
            .unwrap();
        assert_eq!(resp.result, expect, "bit_skip={bit_skip}");
        if bit_skip {
            let rep = resp.report.unwrap();
            assert_eq!(rep.lhs_planes, 2, "LSB plane skipped");
        }
    }
}

#[test]
fn verify_option_holds_across_backends() {
    let svc = service(2, 2, 1 << 20);
    let mut rng = Rng::new(0x7E57);
    let a = IntMatrix::random(&mut rng, 4, 96, 3, true);
    let b = IntMatrix::random(&mut rng, 96, 4, 3, true);
    for backend in [Backend::Engine, Backend::Sim] {
        let opts = RequestOptions {
            backend,
            verify: true,
            ..Default::default()
        };
        let resp = svc
            .run(GemmRequest::with_opts(
                a.clone(),
                b.clone(),
                Precision::signed(3, 3),
                opts,
            ))
            .unwrap();
        assert_eq!(resp.result, a.matmul(&b), "{}", backend.name());
    }
}
