//! Property-level integration: for random shapes, precisions, overlap
//! modes and every Table IV instance, the scheduler+simulator pipeline
//! must (1) produce bit-exact results vs the i64 reference, (2) satisfy
//! timing invariants, and (3) keep resource accounting consistent.

use bismo::arch::{all_instances, instance, BismoConfig};
use bismo::baseline::binary_ops;
use bismo::bitmatrix::IntMatrix;
use bismo::coordinator::{BismoContext, MatmulOptions, Precision};
use bismo::scheduler::Overlap;
use bismo::util::{property_sweep, Rng};

fn run_one(
    ctx: &BismoContext,
    rng: &mut Rng,
    m: usize,
    k: usize,
    n: usize,
    w: u32,
    a: u32,
    overlap: Overlap,
    bit_skip: bool,
) {
    let (ls, rs) = (rng.chance(0.5), rng.chance(0.5));
    let am = IntMatrix::random(rng, m, k, w, ls);
    let bm = IntMatrix::random(rng, k, n, a, rs);
    let prec = Precision {
        wbits: w,
        abits: a,
        lsigned: ls,
        rsigned: rs,
    };
    let opts = MatmulOptions {
        overlap,
        bit_skip,
        verify: false,
    };
    let (p, rep) = ctx
        .matmul(&am, &bm, prec, opts)
        .unwrap_or_else(|e| panic!("matmul {m}x{k}x{n} w{w}a{a}: {e}"));
    assert_eq!(p, am.matmul(&bm), "numerics {m}x{k}x{n} w{w}a{a} {overlap:?}");

    // Timing invariants.
    let cfg = ctx.config();
    let s = &rep.stats;
    assert!(rep.cycles >= s.fetch_busy, "makespan >= fetch busy");
    assert!(rep.cycles >= s.execute_busy, "makespan >= execute busy");
    assert!(rep.cycles >= s.result_busy, "makespan >= result busy");
    assert!(rep.efficiency > 0.0 && rep.efficiency <= 1.0);

    // Work accounting: without bit-skip the DPA processes full tiles:
    // ops >= the mathematical op count, <= padded tile bound.
    if !bit_skip {
        let math_ops = binary_ops(m as u64, k as u64, n as u64, w, a);
        assert!(s.binary_ops >= math_ops, "{} < {}", s.binary_ops, math_ops);
        let pad = |x: usize, d: u32| x.div_ceil(d as usize) as u64 * d as u64;
        let padded = binary_ops(
            pad(m, cfg.dm),
            pad(k, cfg.dk),
            pad(n, cfg.dn),
            w,
            a,
        );
        assert!(s.binary_ops <= padded, "{} > padded {}", s.binary_ops, padded);
    }

    // Data movement: result bytes = exactly the result matrix.
    assert_eq!(s.bytes_written, (m * n * 4) as u64);
    // Fetched at least one copy of both operands (packed sizes).
    let lhs_planes = rep.lhs_planes as u64;
    let rhs_planes = rep.rhs_planes as u64;
    let wpc = (cfg.dk as u64).div_ceil(64) * 8;
    let lhs_min = lhs_planes * m as u64 * (k as u64).div_ceil(cfg.dk as u64) * wpc;
    let rhs_min = rhs_planes * n as u64 * (k as u64).div_ceil(cfg.dk as u64) * wpc;
    assert!(
        s.bytes_fetched >= lhs_min + rhs_min,
        "fetched {} < minimum {}",
        s.bytes_fetched,
        lhs_min + rhs_min
    );
    assert_eq!(s.commits, (m.div_ceil(cfg.dm as usize) * n.div_ceil(cfg.dn as usize)) as u64);
}

#[test]
fn random_jobs_all_instances() {
    for (id, cfg) in all_instances() {
        let ctx = BismoContext::new(cfg).unwrap();
        property_sweep(0x1000 + id as u64, 4, |rng, _| {
            let m = rng.index(24) + 1;
            let k = rng.index(1024) + 1;
            let n = rng.index(24) + 1;
            let w = rng.index(4) as u32 + 1;
            let a = rng.index(4) as u32 + 1;
            let ov = *rng.pick(&[Overlap::Full, Overlap::None]);
            let skip = rng.chance(0.3);
            run_one(&ctx, rng, m, k, n, w, a, ov, skip);
        });
    }
}

#[test]
fn streaming_mode_large_k_all_overlaps() {
    // Small buffers force Streaming mode with k-slicing.
    let cfg = BismoConfig {
        bm: 128,
        bn: 128,
        ..BismoConfig::small()
    };
    let ctx = BismoContext::new(cfg).unwrap();
    property_sweep(0x2000, 6, |rng, _| {
        let k = 64 * (rng.index(200) + 40); // up to ~15k: kc up to 240 > bm/2
        let w = rng.index(3) as u32 + 1;
        let a = rng.index(2) as u32 + 1;
        let ov = *rng.pick(&[Overlap::Full, Overlap::None]);
        run_one(&ctx, rng, 5, k, 3, w, a, ov, false);
    });
}

#[test]
fn extreme_aspect_ratios() {
    let ctx = BismoContext::new(instance(1)).unwrap();
    let mut rng = Rng::new(0x3000);
    // Matrix-vector (n = 1), vector-matrix (m = 1), tiny k.
    run_one(&ctx, &mut rng, 1, 512, 64, 2, 2, Overlap::Full, false);
    run_one(&ctx, &mut rng, 64, 512, 1, 2, 2, Overlap::Full, false);
    run_one(&ctx, &mut rng, 33, 1, 33, 3, 3, Overlap::Full, false);
    run_one(&ctx, &mut rng, 1, 1, 1, 8, 8, Overlap::None, false);
}

#[test]
fn max_precision_jobs() {
    let ctx = BismoContext::new(instance(1)).unwrap();
    let mut rng = Rng::new(0x4000);
    // Asymmetric extreme precision (no accumulator overflow: products
    // fit A=32 for k=128).
    run_one(&ctx, &mut rng, 4, 128, 4, 1, 16, Overlap::Full, false);
}

#[test]
fn acc_width_wraps_like_hardware_at_extreme_precision() {
    // 16x16-bit over k=128 produces |values| up to ~2^37, overflowing
    // the 32-bit accumulator. The hardware register wraps; the
    // simulator must reproduce exactly that (i64 result mod 2^32),
    // and report the overflow events.
    let ctx = BismoContext::new(instance(1)).unwrap();
    let mut rng = Rng::new(0x4001);
    let a = IntMatrix::random(&mut rng, 4, 128, 16, true);
    let b = IntMatrix::random(&mut rng, 128, 4, 16, true);
    let (p, rep) = ctx
        .matmul(
            &a,
            &b,
            Precision::signed(16, 16),
            MatmulOptions::default(),
        )
        .unwrap();
    let wrapped = IntMatrix::from_fn(4, 4, |r, c| a.matmul(&b).get(r, c) as i32 as i64);
    assert_eq!(p, wrapped, "simulator must wrap at A=32 like hardware");
    assert!(rep.stats.acc_overflows > 0, "overflow events must be counted");
}

#[test]
fn overlap_full_never_slower() {
    // For identical inputs, the overlapped schedule must finish no
    // later than the serialized one (token protocol only adds slack).
    for (_, cfg) in all_instances().into_iter().take(3) {
        let ctx = BismoContext::new(cfg).unwrap();
        property_sweep(0x5000, 4, |rng, _| {
            let m = rng.index(20) + 1;
            let k = rng.index(2048) + 1;
            let n = rng.index(20) + 1;
            let am = IntMatrix::random(rng, m, k, 2, false);
            let bm = IntMatrix::random(rng, k, n, 2, false);
            let mk = |ov| MatmulOptions {
                overlap: ov,
                ..Default::default()
            };
            let (pf, rf) = ctx
                .matmul(&am, &bm, Precision::unsigned(2, 2), mk(Overlap::Full))
                .unwrap();
            let (pn, rn) = ctx
                .matmul(&am, &bm, Precision::unsigned(2, 2), mk(Overlap::None))
                .unwrap();
            assert_eq!(pf, pn);
            assert!(
                rf.cycles <= rn.cycles,
                "overlap {} > serialized {} for {m}x{k}x{n}",
                rf.cycles,
                rn.cycles
            );
        });
    }
}

#[test]
fn determinism_across_runs() {
    let ctx = BismoContext::new(instance(2)).unwrap();
    let mut rng = Rng::new(0x6000);
    let am = IntMatrix::random(&mut rng, 16, 1024, 3, true);
    let bm = IntMatrix::random(&mut rng, 1024, 16, 3, true);
    let run = || {
        ctx.matmul(
            &am,
            &bm,
            Precision::signed(3, 3),
            MatmulOptions::default(),
        )
        .unwrap()
    };
    let (p1, r1) = run();
    let (p2, r2) = run();
    assert_eq!(p1, p2);
    assert_eq!(r1.cycles, r2.cycles);
    assert_eq!(r1.stats, r2.stats);
}
