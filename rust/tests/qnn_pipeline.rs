//! QNN application pipeline: train → quantize → serve on the overlay,
//! across precisions and batch execution.

use bismo::arch::instance;
use bismo::bitmatrix::IntMatrix;
use bismo::coordinator::{BismoBatchRunner, BismoContext, MatmulOptions, Precision};
use bismo::qnn::{FloatMlp, QnnMlp, SyntheticDigits};
use bismo::util::Rng;

fn trained() -> (FloatMlp, SyntheticDigits) {
    let d = SyntheticDigits::generate(42, 600, 120, 0.15);
    let mut mlp = FloatMlp::new(7, [784, 64, 64, 10]);
    for e in 0..3 {
        mlp.train_epoch(&d.train_x, &d.train_y, 0.02, e);
    }
    (mlp, d)
}

#[test]
fn precision_sweep_accuracy_and_cycles() {
    let (mlp, d) = trained();
    let float_acc = mlp.accuracy(&d.test_x, &d.test_y);
    assert!(float_acc > 0.8, "float acc {float_acc}");

    let ctx = BismoContext::new(instance(2)).unwrap();
    let mut prev_cycles = 0u64;
    let mut accs = Vec::new();
    for (w, a) in [(2u32, 2u32), (4, 2), (8, 4)] {
        let q = QnnMlp::from_float(&mlp, w, a, (6, 4));
        let x = q.quantize_input(&d.test_x[..32]);
        let (logits, reports) = q
            .forward_on_overlay(&ctx, &x, MatmulOptions::default())
            .unwrap();
        // Bit-exact vs the integer reference at every precision.
        assert_eq!(logits, q.forward_reference(&x), "w{w}a{a}");
        let cycles: u64 = reports.iter().map(|r| r.cycles).sum();
        assert!(
            cycles > prev_cycles,
            "higher precision must cost more cycles ({cycles} !> {prev_cycles})"
        );
        prev_cycles = cycles;
        accs.push(QnnMlp::accuracy(&logits, &d.test_y[..32]));
    }
    // Highest precision should not be (much) worse than lowest.
    assert!(
        accs[2] + 0.10 >= accs[0],
        "accuracy collapsed with precision: {accs:?}"
    );
}

#[test]
fn bit_skip_helps_low_effective_precision_activations() {
    let (mlp, d) = trained();
    // Activations declared 8-bit but quantized to 2 effective bits:
    // their upper planes are all zero (unsigned side — note that
    // *signed* low-magnitude weights do NOT yield zero planes, because
    // two's-complement sign extension fills the high planes).
    let q3 = QnnMlp::from_float(&mlp, 3, 2, (6, 4));
    let q8 = QnnMlp {
        w1: q3.w1.clone(),
        w2: q3.w2.clone(),
        w3: q3.w3.clone(),
        wbits: 3,
        abits: 8, // declared activation precision: 8 bits
        shifts: (6, 4),
    };
    let ctx = BismoContext::new(instance(2)).unwrap();
    // Quantize at 2 effective bits (q3's abits), run declared as 8-bit.
    let x = q3.quantize_input(&d.test_x[..16]);
    let dense = q8
        .forward_on_overlay(&ctx, &x, MatmulOptions::default())
        .unwrap();
    let skip = q8
        .forward_on_overlay(
            &ctx,
            &x,
            MatmulOptions {
                bit_skip: true,
                ..Default::default()
            },
        )
        .unwrap();
    assert_eq!(dense.0, skip.0, "bit-skip must stay exact");
    let dc: u64 = dense.1.iter().map(|r| r.cycles).sum();
    let sc: u64 = skip.1.iter().map(|r| r.cycles).sum();
    assert!(sc < dc, "bit-skip {sc} should beat dense {dc}");
}

#[test]
fn batch_runner_serves_mixed_precision_layers() {
    let (mlp, d) = trained();
    let q = QnnMlp::from_float(&mlp, 4, 2, (6, 4));
    let runner = BismoBatchRunner::new(instance(2), 2).unwrap();
    // Eight independent layer-1 GEMM jobs (as a serving queue would see).
    let jobs: Vec<_> = d
        .test_x
        .chunks(8)
        .take(8)
        .map(|chunk| {
            let x = q.quantize_input(chunk);
            (
                x,
                // The batch runner takes owned IntMatrix jobs; deep-copy
                // the Arc-shared weight (the serving layer avoids this —
                // see BismoService).
                (*q.w1).clone(),
                Precision {
                    wbits: 2,
                    abits: 4,
                    lsigned: false,
                    rsigned: true,
                },
                MatmulOptions::default(),
            )
        })
        .collect();
    let outcomes = runner.run_batch(&jobs);
    for (i, o) in outcomes.iter().enumerate() {
        let (p, _) = o.result.as_ref().expect("job ok");
        assert_eq!(*p, jobs[i].0.matmul(&jobs[i].1), "job {i}");
    }
    assert!(runner.batch_gops(&outcomes) > 0.0);
}

#[test]
fn quantize_input_respects_batch_rows() {
    let (mlp, d) = trained();
    let q = QnnMlp::from_float(&mlp, 4, 2, (6, 4));
    let x = q.quantize_input(&d.test_x[..5]);
    assert_eq!((x.rows, x.cols), (5, 784));
    assert!(x.fits(2, false));
}

#[test]
fn random_weights_roundtrip_overlay() {
    // QNN layers with adversarial (random, extreme) integer weights.
    let ctx = BismoContext::new(instance(1)).unwrap();
    let mut rng = Rng::new(0x91A);
    for _ in 0..3 {
        let x = IntMatrix::random(&mut rng, 8, 784, 2, false);
        let w = IntMatrix::random(&mut rng, 784, 32, 4, true);
        let (p, _) = ctx
            .matmul(
                &x,
                &w,
                Precision {
                    wbits: 2,
                    abits: 4,
                    lsigned: false,
                    rsigned: true,
                },
                MatmulOptions::default(),
            )
            .unwrap();
        assert_eq!(p, x.matmul(&w));
    }
}
