//! Property suite for the convolution lowering stack: every lowered
//! execution path — im2col and kn2row, engine and sim backends,
//! sharded, prepared-weight reuse — must be bit-exact against the
//! naive `i64` direct-convolution oracle across stride / padding /
//! dilation / ragged channel counts. Plus the typed-error contract
//! for illegal specs.

use bismo::api::{Backend, BismoError, Precision, Session, SessionConfig};
use bismo::bitmatrix::BitSerialMatrix;
use bismo::lowering::{conv2d_direct, im2col_matrix, pack_im2col, ConvSpec, LoweringMode, Tensor};
use bismo::simd::DispatchTier;
use bismo::util::{property_sweep, Rng};

fn random_spec(rng: &mut Rng) -> ConvSpec {
    loop {
        let spec = ConvSpec {
            in_h: rng.index(10) + 2,
            in_w: rng.index(10) + 2,
            // Raggedy channel counts on purpose: 1, 3, 5, ... never a
            // friendly power of two beyond chance.
            in_c: rng.index(5) + 1,
            out_c: rng.index(6) + 1,
            kh: rng.index(3) + 1,
            kw: rng.index(3) + 1,
            stride: (rng.index(3) + 1, rng.index(3) + 1),
            pad: (rng.index(3), rng.index(3)),
            dilation: (rng.index(2) + 1, rng.index(2) + 1),
        };
        if spec.validate().is_ok() {
            return spec;
        }
    }
}

fn random_prec(rng: &mut Rng) -> Precision {
    Precision {
        wbits: rng.index(3) as u32 + 1,
        abits: rng.index(3) as u32 + 1,
        lsigned: false,
        rsigned: rng.chance(0.7),
    }
}

#[test]
fn lowered_conv_matches_direct_oracle_across_spec_space() {
    let session = Session::with_defaults().unwrap();
    property_sweep(0xC09F, 18, |rng, i| {
        let spec = random_spec(rng);
        let prec = random_prec(rng);
        let batch = rng.index(3) + 1;
        let x = Tensor::random(rng, batch, spec.in_h, spec.in_w, spec.in_c, prec.wbits, false);
        let w = spec.weights_from_fn(|_, _, _, _| rng.operand(prec.abits, prec.rsigned));
        let want = conv2d_direct(&x, &w, &spec);
        // Alternate backend/mode per case to keep the sweep fast while
        // covering the full matrix over the run.
        let backend = if i % 2 == 0 { Backend::Engine } else { Backend::Sim };
        let mode = if i % 4 < 2 {
            LoweringMode::Im2col
        } else {
            LoweringMode::Kn2row
        };
        let resp = session
            .conv(spec, prec)
            .backend(backend)
            .lowering(mode)
            .verify(true)
            .run(&x, w)
            .unwrap();
        assert_eq!(resp.output, want, "case {i}: {spec:?} {prec:?} {mode:?}");
    });
}

#[test]
fn sharded_lowered_conv_matches_oracle_on_both_backends() {
    let session = Session::with_defaults().unwrap();
    property_sweep(0x54AC, 6, |rng, i| {
        let spec = random_spec(rng);
        let prec = random_prec(rng);
        let batch = rng.index(2) + 2;
        let x = Tensor::random(rng, batch, spec.in_h, spec.in_w, spec.in_c, prec.wbits, false);
        let w = spec.weights_from_fn(|_, _, _, _| rng.operand(prec.abits, prec.rsigned));
        let want = conv2d_direct(&x, &w, &spec);
        let backend = if i % 2 == 0 { Backend::Engine } else { Backend::Sim };
        let resp = session
            .conv(spec, prec)
            .backend(backend)
            .instances(4)
            .verify(true)
            .run(&x, w)
            .unwrap();
        assert_eq!(resp.output, want, "case {i}: {spec:?}");
        assert!(
            resp.gemms.iter().all(|g| g.shards >= 1),
            "sharding metadata present"
        );
    });
}

#[test]
fn prepared_weights_reused_across_inputs_and_modes() {
    let session = Session::with_defaults().unwrap();
    let mut rng = Rng::new(0x9E9C);
    let spec = ConvSpec {
        in_h: 9,
        in_w: 7,
        in_c: 3,
        out_c: 5,
        kh: 3,
        kw: 2,
        stride: (2, 1),
        pad: (1, 1),
        dilation: (1, 1),
    };
    let prec = Precision {
        wbits: 2,
        abits: 3,
        lsigned: false,
        rsigned: true,
    };
    let w = spec.weights_from_fn(|_, _, _, _| rng.operand(3, true));
    for mode in [LoweringMode::Im2col, LoweringMode::Kn2row] {
        let prepared = session.conv(spec, prec).lowering(mode).prepare(w.clone()).unwrap();
        let after_prepare = session.cache_stats();
        for rep in 0..3 {
            let x = Tensor::random(&mut rng, 2, spec.in_h, spec.in_w, spec.in_c, 2, false);
            let resp = prepared.execute(&x).unwrap();
            assert_eq!(resp.output, conv2d_direct(&x, &w, &spec), "{mode:?} rep {rep}");
            assert!(resp.weights_cached(), "{mode:?} rep {rep} served from cache");
        }
        let after = session.cache_stats();
        assert_eq!(after.misses, after_prepare.misses, "{mode:?}: no repacks");
    }
}

#[test]
fn packed_im2col_never_diverges_from_dense_lowering() {
    // The zero-materialization path vs materialize-then-pack, across
    // the whole spec space including dilation and asymmetric strides.
    property_sweep(0x1A2C, 25, |rng, _| {
        let spec = random_spec(rng);
        let bits = rng.index(4) as u32 + 1;
        let batch = rng.index(2) + 1;
        let x = Tensor::random(rng, batch, spec.in_h, spec.in_w, spec.in_c, bits, false);
        let packed = pack_im2col(&x, &spec, bits, false);
        let dense = im2col_matrix(&x, &spec);
        assert_eq!(packed.to_int(), dense, "{spec:?}");
    });
}

#[test]
fn im2col_packing_is_word_identical_on_every_dispatch_tier() {
    // The conv hot path packs the virtual im2col patch matrix through
    // `from_int_fn`, which now runs the SIMD chunk packer — verify the
    // planes it produces are word-identical to both the scalar packer
    // and the materialize-then-pack route at every supported tier.
    property_sweep(0x1A2C_71E6, 10, |rng, _| {
        let spec = random_spec(rng);
        let bits = rng.index(4) as u32 + 1;
        let batch = rng.index(2) + 1;
        let x = Tensor::random(rng, batch, spec.in_h, spec.in_w, spec.in_c, bits, false);
        let dense = im2col_matrix(&x, &spec);
        let want = pack_im2col(&x, &spec, bits, false);
        for tier in DispatchTier::supported() {
            let via_fn = BitSerialMatrix::from_int_fn_tier(
                dense.rows,
                dense.cols,
                bits,
                false,
                tier,
                |r, c| dense.get(r, c),
            );
            assert_eq!(
                via_fn,
                BitSerialMatrix::from_int_tier(&dense, bits, false, tier),
                "tier={tier}: {spec:?}"
            );
            assert_eq!(via_fn, want, "tier={tier} vs active-tier pack_im2col: {spec:?}");
        }
    });
}

#[test]
fn illegal_specs_surface_as_typed_errors_through_the_facade() {
    let session = Session::with_defaults().unwrap();
    let ok = ConvSpec::simple(6, 6, 2, 3, 3, 1);
    let prec = Precision {
        wbits: 2,
        abits: 2,
        lsigned: false,
        rsigned: true,
    };
    let x = Tensor::zeros(1, 6, 6, 2);
    let w = ok.weights_from_fn(|_, _, _, _| 0);
    let submitted = session.service().submitted();
    // Padding at/beyond the kernel extent.
    let r = session.conv(ConvSpec { pad: (3, 1), ..ok }, prec).run(&x, w.clone());
    assert!(matches!(r, Err(BismoError::InvalidConfig(_))), "{r:?}");
    // Zero channels, both sides.
    for bad in [ConvSpec { in_c: 0, ..ok }, ConvSpec { out_c: 0, ..ok }] {
        let r = session.conv(bad, prec).run(&x, w.clone());
        assert!(matches!(r, Err(BismoError::InvalidConfig(_))), "{r:?}");
    }
    // prepare() validates identically — nothing is packed for an
    // illegal spec.
    let r = session.conv(ConvSpec { kh: 0, ..ok }, prec).prepare(w.clone());
    assert!(r.is_err());
    // Bad precision is rejected before lowering.
    let bad_prec = Precision {
        wbits: 0,
        abits: 2,
        lsigned: false,
        rsigned: true,
    };
    let r = session.conv(ok, bad_prec).run(&x, w);
    assert!(matches!(r, Err(BismoError::PrecisionUnsupported(_))), "{r:?}");
    assert_eq!(session.service().submitted(), submitted, "nothing was queued");
}

#[test]
fn strided_dilated_asymmetric_spec_exercises_every_knob_at_once() {
    // One deliberately awkward spec: asymmetric kernel, stride,
    // padding and dilation together, on both backends and modes.
    let session = Session::new(SessionConfig::default()).unwrap();
    let mut rng = Rng::new(0xD11A);
    let spec = ConvSpec {
        in_h: 11,
        in_w: 8,
        in_c: 3,
        out_c: 2,
        kh: 3,
        kw: 2,
        stride: (2, 3),
        pad: (2, 1),
        dilation: (2, 1),
    };
    spec.validate().unwrap();
    let prec = Precision {
        wbits: 3,
        abits: 2,
        lsigned: false,
        rsigned: true,
    };
    let x = Tensor::random(&mut rng, 3, 11, 8, 3, 3, false);
    let w = spec.weights_from_fn(|_, _, _, _| rng.operand(2, true));
    let want = conv2d_direct(&x, &w, &spec);
    for backend in [Backend::Engine, Backend::Sim] {
        for mode in [LoweringMode::Im2col, LoweringMode::Kn2row] {
            let resp = session
                .conv(spec, prec)
                .backend(backend)
                .lowering(mode)
                .verify(true)
                .run(&x, w.clone())
                .unwrap();
            assert_eq!(resp.output, want, "{} {mode:?}", backend.name());
        }
    }
}
