//! Table V: power consumption and energy efficiency.
//!
//! Regenerated from the calibrated analytic power model (the
//! substitution for the paper's USB power meter; see power/mod.rs).

use bismo::arch::instance;
use bismo::power::{PowerModel, TABLE_V};
use bismo::report::{f, Table};
use bismo::util::CsvWriter;

fn main() {
    let m = PowerModel::calibrated();
    let mut table = Table::new(
        "Table V — power & efficiency (model vs paper measurements)",
        &[
            "config", "idle W", "(paper)", "+exec", "(paper)", "+f&r", "(paper)",
            "full W", "(paper)", "GOPS", "GOPS/W",
        ],
    );
    let mut csv = CsvWriter::new(
        "results/table5_power.csv",
        &["instance", "fclk_mhz", "idle_w", "exec_inc_w", "fr_inc_w", "full_w", "gops_per_w"],
    );
    for row in &TABLE_V {
        let cfg = instance(row.instance).at_clock(row.fclk_mhz);
        let idle = m.idle_w(&cfg);
        let exec = m.exec_increment_w(&cfg);
        let fr = m.fetch_result_increment_w(&cfg);
        let full = m.full_w(&cfg);
        let gops = row.gops;
        table.rowf(&[
            &format!("(#{}, {} MHz)", row.instance, row.fclk_mhz),
            &f(idle, 2),
            &f(row.idle_w, 2),
            &f(exec, 2),
            &f(row.exec_inc_w, 2),
            &f(fr, 2),
            &f(row.fr_inc_w, 2),
            &f(full, 2),
            &f(row.full_w, 2),
            &f(gops, 0),
            &f(gops / full, 1),
        ]);
        csv.rowf(&[
            &row.instance,
            &row.fclk_mhz,
            &idle,
            &exec,
            &fr,
            &full,
            &(gops / full),
        ]);
    }
    table.print();
    // The qualitative findings the paper draws from this table.
    let small_fast = 1638.0 / m.full_w(&instance(1).at_clock(200));
    let large_slow = 1638.0 / m.full_w(&instance(3).at_clock(50));
    println!(
        "large-slow vs small-fast efficiency: {}x (paper: ~1.5x)",
        f(large_slow / small_fast, 2)
    );
    println!(
        "headline: instance #3 @ 200 MHz -> {} GOPS/W (paper: 1413)",
        f(m.gops_per_w(&instance(3).at_clock(200)), 0)
    );
    let path = csv.finish().expect("csv");
    println!("data -> {}", path.display());
}
