//! §Perf: simulator throughput — simulated cycles per wall-clock second
//! on the stage-overlap workload (the figure suite's cost driver) and
//! end-to-end matmul latency including packing + scheduling.

use bismo::arch::instance;
use bismo::bitmatrix::IntMatrix;
use bismo::coordinator::{BismoBatchRunner, BismoContext, MatmulOptions, Precision};
use bismo::util::bench::{report, BenchTimer};
use bismo::util::Rng;

fn main() {
    let cfg = instance(1);
    let ctx = BismoContext::new(cfg).expect("ctx");
    let mut rng = Rng::new(0x5137);
    let (m, k, n) = (256usize, 4096usize, 256usize);
    let a = IntMatrix::random(&mut rng, m, k, 1, false);
    let b = IntMatrix::random(&mut rng, k, n, 1, false);

    // Full pipeline: pack + schedule + simulate (what every figure pays).
    let t = BenchTimer::heavy();
    let mut sim_cycles = 0u64;
    let s = t.run(|| {
        let (_, rep) = ctx
            .matmul(&a, &b, Precision::unsigned(1, 1), MatmulOptions::default())
            .unwrap();
        sim_cycles = rep.cycles;
        rep.cycles
    });
    report("e2e_matmul_256x4096x256_binary", &s, Some((sim_cycles as f64, "simcycles")));

    // Multi-bit variant (8 plane pairs → more execute instructions).
    let a4 = IntMatrix::random(&mut rng, 64, 4096, 4, false);
    let b4 = IntMatrix::random(&mut rng, 4096, 64, 2, false);
    let s = t.run(|| {
        ctx.matmul(&a4, &b4, Precision::unsigned(4, 2), MatmulOptions::default())
            .unwrap()
            .1
            .cycles
    });
    report("e2e_matmul_64x4096x64_w4a2", &s, None);

    // Batch drain on the persistent worker pool: context validated
    // once, no per-batch thread spawning.
    let runner = BismoBatchRunner::new(cfg, 4).expect("runner");
    let jobs: Vec<_> = (0..16)
        .map(|_| {
            let a = IntMatrix::random(&mut rng, 16, 512, 2, false);
            let b = IntMatrix::random(&mut rng, 512, 16, 2, false);
            (a, b, Precision::unsigned(2, 2), MatmulOptions::default())
        })
        .collect();
    let s = t.run(|| runner.run_batch(&jobs));
    report("batch_16x(16x512x16)_w2a2_4workers", &s, Some((16.0, "job")));
}
