//! Table VI: BISMO vs related low-precision matmul implementations.
//!
//! Our BISMO rows are *measured* from this reproduction (peak GOPS from
//! the configuration, GOPS/W from the calibrated power model). The
//! Umuroglu & Jahre CPU row is re-measured by actually running this
//! crate's bit-serial CPU gemm on the build machine. Other systems'
//! numbers are the paper's citations (we cannot run FINN, Stripes,
//! Espresso or HARPv2 here); they are marked "cited".

use bismo::arch::instance;
use bismo::baseline::{binary_ops, gemm_bitserial};
use bismo::bitmatrix::{BitSerialMatrix, IntMatrix};
use bismo::power::PowerModel;
use bismo::report::{f, Table};
use bismo::util::{BenchTimer, CsvWriter, Rng};

fn main() {
    // Measure the CPU bit-serial baseline on this machine.
    let mut rng = Rng::new(0x7AB6);
    let (m, k, n) = (256usize, 4096usize, 256usize);
    let a = IntMatrix::random(&mut rng, m, k, 1, false);
    let b = IntMatrix::random(&mut rng, k, n, 1, false);
    let la = BitSerialMatrix::from_int(&a, 1, false);
    let rb = BitSerialMatrix::from_int(&b.transpose(), 1, false);
    let ops = binary_ops(m as u64, k as u64, n as u64, 1, 1) as f64;
    let t = BenchTimer::heavy();
    let s = t.run(|| gemm_bitserial(&la, &rb));
    let cpu_gops = ops / s.median();

    let pm = PowerModel::calibrated();
    let bismo3 = instance(3);
    let bismo_gops = bismo3.peak_binary_gops();
    let bismo_gops_w = pm.gops_per_w(&bismo3);

    let mut table = Table::new(
        "Table VI — comparison to related work (binary GOPS, GOPS/W)",
        &["work", "platform", "precision", "GOPS", "GOPS/W", "source"],
    );
    let mut rowf = |w: &str, p: &str, pr: &str, g: f64, gw: f64, s: &str| {
        table.rowf(&[&w, &p, &pr, &f(g, 0), &f(gw, 1), &s]);
    };
    rowf("BISMO (this repro)", "Z7020 sim model", "bit-serial", bismo_gops, bismo_gops_w, "measured");
    rowf("BISMO (paper)", "Z7020 on PYNQ-Z1", "bit-serial", 6554.0, 1413.4, "paper");
    rowf("FINN [6]", "Z7045 on ZC706", "binary", 11613.0, 407.5, "cited");
    rowf("Moss et al. [9]", "GX1150 on HARPv2", "reconfigurable", 41.0, 849.4, "cited");
    rowf("Umuroglu et al. [5] (paper)", "Cortex-A57", "bit-serial", 92.0, 18.8, "cited");
    rowf("this crate's CPU gemm", "build machine (1 thread)", "bit-serial", cpu_gops, f64::NAN, "measured");
    rowf("Pedersoli et al. [10]", "GTX 960", "limited bit-serial", 90909.0, 757.6, "cited");
    rowf("Judd et al. [11]", "ASIC (Stripes)", "limited bit-serial", 128450.0, 4253.3, "cited");
    table.print();

    println!("shape checks (paper's claims):");
    println!(
        "  BISMO vs CPU bit-serial: {}x (paper: >1 order of magnitude)",
        f(bismo_gops / cpu_gops, 0)
    );
    println!(
        "  ASIC (Stripes) vs BISMO: {}x (paper: ~3x... ASIC wins)",
        f(128450.0 / bismo_gops, 1)
    );
    println!(
        "  BISMO GOPS/W vs FINN: {}x (paper: 3.5x)",
        f(bismo_gops_w / 407.5, 1)
    );

    let mut csv = CsvWriter::new(
        "results/table6_comparison.csv",
        &["work", "gops", "gops_per_w"],
    );
    csv.rowf(&[&"bismo_repro", &bismo_gops, &bismo_gops_w]);
    csv.rowf(&[&"cpu_gemm_measured", &cpu_gops, &0.0]);
    let path = csv.finish().expect("csv");
    println!("data -> {}", path.display());
}
