//! Fig. 13: runtime vs operand precision on instance #2 — the "peak
//! bit-serial compute" experiment (operands resident on-chip, execute
//! stage only, like Fig. 12).
//!
//! Bit-serial promise: a w×a-bit matmul costs ≈ w·a × the binary one.
//! Paper: slightly *better* than w·a·t because the w·a plane pairs of
//! one accumulation group run back-to-back and keep the DPA pipeline
//! full (they "behave like a longer dot product").

use bismo::arch::{instance, PYNQ_Z1};
use bismo::bitmatrix::dram::DramImage;
use bismo::report::{f, Table};
use bismo::scheduler::peak_execute_program;
use bismo::sim::Simulation;
use bismo::util::CsvWriter;

fn main() {
    let cfg = instance(2); // D_k = 128
    let shapes = [(8usize, 2048usize, 8usize), (8, 16384, 8)];
    let precisions = [(1u32, 1u32), (2, 2), (3, 3), (4, 4), (6, 6), (8, 8)];

    let mut table = Table::new(
        "Fig. 13 — runtime vs precision (instance #2, execute stage)",
        &["shape", "w x a", "cycles", "vs binary", "w*a", "ratio/(w*a)"],
    );
    let mut csv = CsvWriter::new(
        "results/fig13_precision.csv",
        &["m", "k", "n", "w", "a", "cycles", "ratio_vs_binary"],
    );
    for &(m, k, n) in &shapes {
        let chunks = (k as u32) / cfg.dk;
        // One output tile (m=n=8 = D_m=D_n); repeat 16 independent
        // accumulation groups to amortize measurement edges.
        let bursts = 16u32;
        let mut binary_cycles = 0u64;
        for &(w, a) in &precisions {
            let prog = peak_execute_program(&cfg, chunks, bursts, w * a).expect("program");
            let mut sim = Simulation::new(cfg, &PYNQ_Z1, DramImage::new(64)).expect("sim");
            let stats = sim.run(&prog).expect("run");
            if w == 1 {
                binary_cycles = stats.cycles;
            }
            let ratio = stats.cycles as f64 / binary_cycles as f64;
            let wa = (w * a) as f64;
            table.rowf(&[
                &format!("{m}x{k}x{n}"),
                &format!("{w}x{a}"),
                &stats.cycles,
                &f(ratio, 2),
                &f(wa, 0),
                &f(ratio / wa, 3),
            ]);
            csv.rowf(&[&m, &k, &n, &w, &a, &stats.cycles, &ratio]);
        }
    }
    table.print();
    println!("paper: measured runtime slightly below w·a·t — the ratio/(w*a) column < 1.0,");
    println!("approaching 1.0 for long dot products where fill cost is already amortized");
    let path = csv.finish().expect("csv");
    println!("data -> {}", path.display());
}
