//! §Perf: PJRT runtime — artifact compile time (one-off) and execute
//! latency/throughput on the request path (Python is never involved).
//! Requires the `xla` cargo feature (PJRT plugin + xla/anyhow crates).

#[cfg(not(feature = "xla"))]
fn main() {
    println!("skipping perf_runtime: build with --features xla");
}

#[cfg(feature = "xla")]
fn main() {
    use bismo::bitmatrix::IntMatrix;
    use bismo::runtime::Runtime;
    use bismo::util::bench::{fmt_ns, report, BenchTimer};
    use bismo::util::Rng;
    use std::path::Path;
    use std::time::Instant;

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("skipping perf_runtime: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new(&dir).expect("runtime");

    // One-off compile cost.
    let t0 = Instant::now();
    let exe = rt.load("bitserial_matmul_64x256x64_w4a4_ss").expect("load");
    println!(
        "artifact compile (cold) bitserial_matmul_64x256x64: {}",
        fmt_ns(t0.elapsed().as_nanos() as f64)
    );

    let mut rng = Rng::new(0x9E);
    let a = IntMatrix::random(&mut rng, 64, 256, 4, true);
    let b = IntMatrix::random(&mut rng, 256, 64, 4, true);
    let t = BenchTimer::default();
    let s = t.run(|| exe.run_i32(&[&a, &b]).unwrap());
    // 8 plane pairs * 2*m*k*n binary op equivalents.
    let ops = 2.0 * 64.0 * 256.0 * 64.0 * 16.0;
    report("pjrt_exec_matmul_64x256x64_w4a4", &s, Some((ops, "binop")));

    let qnn = rt.load("qnn_mlp_b16_w4a2").expect("load qnn");
    let x = IntMatrix::random(&mut rng, 16, 784, 2, false);
    let w1 = IntMatrix::random(&mut rng, 784, 256, 4, true);
    let w2 = IntMatrix::random(&mut rng, 256, 256, 4, true);
    let w3 = IntMatrix::random(&mut rng, 256, 10, 4, true);
    let s = t.run(|| qnn.run_i32(&[&x, &w1, &w2, &w3]).unwrap());
    report("pjrt_exec_qnn_mlp_b16", &s, Some((16.0, "inference")));
}
