//! Fig. 12: execute-stage efficiency vs matrix width k.
//!
//! Peak-binary-compute experiment: data preloaded, no fetch/result.
//! Efficiency = achieved ops / (peak ops/cycle · cycles); the loss is
//! DPA pipeline fill between accumulation groups. Paper anchor points:
//! instance #1 ≈ 89%, #3 ≈ 64% at k = 8192; ≈100% for wide matrices.

use bismo::arch::{instance, PYNQ_Z1};
use bismo::bitmatrix::dram::DramImage;
use bismo::report::{pct, Table};
use bismo::scheduler::peak_execute_program;
use bismo::sim::Simulation;
use bismo::util::CsvWriter;

fn main() {
    let ks = [512u32, 1024, 2048, 4096, 8192, 16384, 32768, 65536];
    let instances = [1u32, 2, 3];
    let mut table = Table::new(
        "Fig. 12 — execute-stage efficiency vs k",
        &["k", "#1 (Dk=64)", "#2 (Dk=128)", "#3 (Dk=256)"],
    );
    let mut csv = CsvWriter::new(
        "results/fig12_efficiency.csv",
        &["k", "inst1", "inst2", "inst3"],
    );
    for &k in &ks {
        let mut row = vec![format!("{k}")];
        let mut crow = vec![format!("{k}")];
        for &id in &instances {
            let cfg = instance(id);
            let chunks = k / cfg.dk;
            if chunks == 0 || chunks > cfg.bm {
                row.push("-".into());
                crow.push("nan".into());
                continue;
            }
            // 64 independent dot-product groups, one pair each (binary).
            let prog = peak_execute_program(&cfg, chunks, 64, 1).expect("program");
            let mut sim =
                Simulation::new(cfg, &PYNQ_Z1, DramImage::new(64)).expect("sim");
            let stats = sim.run(&prog).expect("run");
            let eff = stats.efficiency(cfg.binary_ops_per_cycle());
            row.push(pct(eff));
            crow.push(format!("{eff}"));
        }
        table.row(&row);
        csv.row(&crow);
    }
    table.print();
    println!("paper anchors @ k=8192: #1 ≈ 89%, #3 ≈ 64%; wide matrices → ~100%");
    let path = csv.finish().expect("csv");
    println!("data -> {}", path.display());
}
