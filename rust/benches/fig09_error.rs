//! Fig. 9: LUT cost-model prediction error vs design size.
//!
//! Paper: large designs predicted accurately; small designs
//! overestimated (Vivado optimizes small designs harder).

use bismo::costmodel::{validation_sweep, CostModel};
use bismo::report::{f, pct, Table};
use bismo::util::CsvWriter;

fn main() {
    let model = CostModel::fit_from_synth();
    let mut pts = validation_sweep(&model);
    pts.sort_by(|a, b| a.actual_luts.partial_cmp(&b.actual_luts).unwrap());
    let mut table = Table::new(
        "Fig. 9 — prediction error vs design size",
        &["actual LUTs", "error"],
    );
    let mut csv = CsvWriter::new("results/fig09_error.csv", &["actual_luts", "rel_error"]);
    for p in &pts {
        table.rowf(&[&f(p.actual_luts, 0), &pct(p.lut_error())]);
        csv.rowf(&[&p.actual_luts, &p.lut_error()]);
    }
    table.print();
    // Quartile summary: smallest vs largest quarter of designs.
    let q = pts.len() / 4;
    let mean_err = |s: &[bismo::costmodel::ValidationPoint]| {
        s.iter().map(|p| p.lut_error()).sum::<f64>() / s.len() as f64
    };
    println!(
        "mean signed error: smallest quartile {} vs largest quartile {}  (paper: small overestimated, large accurate)",
        pct(mean_err(&pts[..q])),
        pct(mean_err(&pts[pts.len() - q..]))
    );
    let path = csv.finish().expect("csv");
    println!("data -> {}", path.display());
}
