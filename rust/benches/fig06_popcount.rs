//! Fig. 6: popcount unit LUT usage and Fmax vs input bitwidth.
//!
//! Paper: least-squares fit ≈ 1 LUT per input bit; Fmax 320–650 MHz.

use bismo::costmodel::linear_fit;
use bismo::report::{f, Table};
use bismo::synth::synth_popcount;
use bismo::util::CsvWriter;

fn main() {
    let widths = [32u32, 64, 96, 128, 192, 256, 384, 512, 768, 1024];
    let mut table = Table::new(
        "Fig. 6 — popcount LUT usage & Fmax vs width",
        &["width", "LUTs", "LUT/bit", "Fmax (MHz)"],
    );
    let mut csv = CsvWriter::new("results/fig06_popcount.csv", &["width", "luts", "fmax_mhz"]);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for &n in &widths {
        let r = synth_popcount(n);
        table.rowf(&[&n, &f(r.luts, 0), &f(r.luts / n as f64, 2), &f(r.fmax_mhz, 0)]);
        csv.rowf(&[&n, &r.luts, &r.fmax_mhz]);
        xs.push(n as f64);
        ys.push(r.luts);
    }
    table.print();
    let (slope, icept) = linear_fit(&xs, &ys).expect("width sweep is well-conditioned");
    println!(
        "least-squares: LUTs = {slope:.3}·width + {icept:.1}   (paper: ~1 LUT/bit)"
    );
    println!("paper band: Fmax 320–650 MHz across widths");
    let path = csv.finish().expect("csv");
    println!("data -> {}", path.display());
}
