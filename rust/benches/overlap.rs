//! §IV-B3 stage-overlap experiment: 256×4096×256 binary matmul on
//! instance #1, operands larger than on-chip memory.
//!
//! Paper: 121133 cycles overlapped vs 266510 serialized → 2.2×.

use bismo::arch::instance;
use bismo::bitmatrix::IntMatrix;
use bismo::coordinator::{BismoContext, MatmulOptions, Precision};
use bismo::report::{f, Table};
use bismo::scheduler::Overlap;
use bismo::util::{CsvWriter, Rng};

fn main() {
    let cfg = instance(1);
    let ctx = BismoContext::new(cfg).expect("ctx");
    let mut rng = Rng::new(0x0E0);
    let (m, k, n) = (256usize, 4096usize, 256usize);
    let a = IntMatrix::random(&mut rng, m, k, 1, false);
    let b = IntMatrix::random(&mut rng, k, n, 1, false);

    let mut table = Table::new(
        "Stage overlap — 256x4096x256 binary on instance #1",
        &["schedule", "cycles", "fetch busy", "exec busy", "result busy", "exec stall"],
    );
    let mut csv = CsvWriter::new("results/overlap.csv", &["schedule", "cycles"]);
    let mut cycles = [0u64; 2];
    for (i, (name, ov)) in [("overlapped", Overlap::Full), ("serialized", Overlap::None)]
        .iter()
        .enumerate()
    {
        let opts = MatmulOptions {
            overlap: *ov,
            verify: true,
            ..Default::default()
        };
        let (_, rep) = ctx
            .matmul(&a, &b, Precision::unsigned(1, 1), opts)
            .expect("matmul");
        cycles[i] = rep.cycles;
        table.rowf(&[
            name,
            &rep.cycles,
            &rep.stats.fetch_busy,
            &rep.stats.execute_busy,
            &rep.stats.result_busy,
            &rep.stats.execute_stall,
        ]);
        csv.rowf(&[name, &rep.cycles]);
    }
    table.print();
    println!(
        "speedup: {}x   (paper: 266510 / 121133 = 2.2x)",
        f(cycles[1] as f64 / cycles[0] as f64, 2)
    );
    let path = csv.finish().expect("csv");
    println!("data -> {}", path.display());
}
