//! Fig. 10: LUT-vs-BRAM tradeoff at constant performance.
//!
//! Three shapes delivering the same 1.6 binary TOPS at 200 MHz
//! (D_m·D_n·D_k = 4096): larger D_k costs fewer LUTs per op but more
//! BRAMs for bandwidth, and vice versa.

use bismo::arch::BismoConfig;
use bismo::costmodel::CostModel;
use bismo::report::{f, Table};
use bismo::synth::synth_instance;
use bismo::util::CsvWriter;

fn main() {
    let shapes = [(8u32, 64u32, 8u32), (4, 256, 4), (2, 1024, 2)];
    let model = CostModel::fit_from_synth();
    let mut table = Table::new(
        "Fig. 10 — LUT/op vs BRAM at 1.6 binary TOPS, 200 MHz",
        &["(Dm,Dk,Dn)", "GOPS", "BRAMs", "LUT/bin.op", "total LUTs"],
    );
    let mut csv = CsvWriter::new(
        "results/fig10_tradeoff.csv",
        &["dm", "dk", "dn", "brams", "lut_per_op", "total_luts"],
    );
    for &(dm, dk, dn) in &shapes {
        let cfg = BismoConfig {
            dm,
            dk,
            dn,
            bm: 1024,
            bn: 1024,
            ..BismoConfig::small()
        };
        assert_eq!(cfg.binary_ops_per_cycle(), 8192, "constant performance");
        let s = synth_instance(&cfg);
        let per_op = s.total_luts / cfg.binary_ops_per_cycle() as f64;
        let brams = model.bram_total(&cfg);
        table.rowf(&[
            &format!("({dm},{dk},{dn})"),
            &f(cfg.peak_binary_gops(), 1),
            &brams,
            &f(per_op, 3),
            &f(s.total_luts, 0),
        ]);
        csv.rowf(&[&dm, &dk, &dn, &brams, &per_op, &s.total_luts]);
    }
    table.print();
    println!("paper: larger D_k -> lower LUT/op but more BRAMs (and vice versa)");
    let path = csv.finish().expect("csv");
    println!("data -> {}", path.display());
}
