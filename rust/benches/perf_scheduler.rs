//! §Perf: scheduler compile throughput (instructions/second emitted)
//! and program sizes — the coordinator-side request-path cost.

use bismo::arch::instance;
use bismo::bitmatrix::dram::{OperandLayout, ResultLayout};
use bismo::scheduler::{compile, MatmulJob, Overlap};
use bismo::util::bench::{report, BenchTimer};
use bismo::util::round_up;

fn job(m: usize, k: usize, n: usize, w: u32, a: u32, dk: u32) -> MatmulJob {
    let lhs = OperandLayout::new(0, m, k, w, dk);
    let rhs = OperandLayout::new(round_up(lhs.total_bytes(), 8), n, k, a, dk);
    let res = ResultLayout::new(round_up(rhs.base + rhs.total_bytes(), 8), m, n);
    MatmulJob {
        m,
        k,
        n,
        wbits: w,
        abits: a,
        lsigned: false,
        rsigned: false,
        lhs,
        rhs,
        res,
    }
}

fn main() {
    let cfg = instance(1);
    let t = BenchTimer::default();
    for (m, k, n, w, a) in [
        (256usize, 4096usize, 256usize, 1u32, 1u32),
        (1024, 4096, 1024, 1, 1),
        (256, 4096, 256, 4, 4),
    ] {
        let j = job(m, k, n, w, a, cfg.dk);
        let prog = compile(&j, &cfg, Overlap::Full).expect("compile");
        let instrs = prog.stats().total as f64;
        let s = t.run(|| compile(&j, &cfg, Overlap::Full).unwrap());
        report(
            &format!("schedule_{m}x{k}x{n}_w{w}a{a} ({} instrs)", instrs as u64),
            &s,
            Some((instrs, "instr")),
        );
    }
}
