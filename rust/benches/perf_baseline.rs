//! §Perf: CPU bit-serial gemm throughput (the Umuroglu & Jahre
//! baseline) — single-threaded and multi-threaded (the latter on the
//! shared persistent worker pool), plus the i64 reference gemm and the
//! tiled kernel engine for context. See perf_kernel for the full
//! engine comparison.

use bismo::baseline::{binary_ops, gemm_bitserial, gemm_bitserial_parallel};
use bismo::bitmatrix::{BitSerialMatrix, IntMatrix};
use bismo::kernel::gemm_tiled;
use bismo::util::bench::{report, BenchTimer};
use bismo::util::Rng;

fn main() {
    let mut rng = Rng::new(0xBA5E);
    for (m, k, n, w, a) in [
        (256usize, 4096usize, 256usize, 1u32, 1u32),
        (256, 4096, 256, 2, 2),
        (64, 8192, 64, 4, 4),
    ] {
        let am = IntMatrix::random(&mut rng, m, k, w, false);
        let bm = IntMatrix::random(&mut rng, k, n, a, false);
        let la = BitSerialMatrix::from_int(&am, w, false);
        let rb = BitSerialMatrix::from_int(&bm.transpose(), a, false);
        let ops = binary_ops(m as u64, k as u64, n as u64, w, a) as f64;
        let t = BenchTimer::heavy();

        let s = t.run(|| gemm_bitserial(&la, &rb));
        report(
            &format!("cpu_bitserial_{m}x{k}x{n}_w{w}a{a}_1t"),
            &s,
            Some((ops, "binop")),
        );
        let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        let s = t.run(|| gemm_bitserial_parallel(&la, &rb, threads));
        report(
            &format!("cpu_bitserial_{m}x{k}x{n}_w{w}a{a}_{threads}t"),
            &s,
            Some((ops, "binop")),
        );
        // The tiled engine on the same operands, for context (the full
        // sweep lives in perf_kernel / `bismo bench`).
        let s = t.run(|| gemm_tiled(&la, &rb).unwrap());
        report(
            &format!("tiled_kernel_{m}x{k}x{n}_w{w}a{a}_1t"),
            &s,
            Some((ops, "binop")),
        );
    }

    // i64 dense reference for context.
    let am = IntMatrix::random(&mut rng, 256, 1024, 8, true);
    let bm = IntMatrix::random(&mut rng, 1024, 256, 8, true);
    let t = BenchTimer::heavy();
    let s = t.run(|| am.matmul(&bm));
    report("cpu_i64_dense_256x1024x256", &s, None);
}
