//! Fig. 11: LUT cost per binary-op-equivalent, bit-serial vs
//! bit-parallel DPUs — the hardware price of flexible precision.
//!
//! Paper: bit-parallel falls from 1.1 LUT/op (2×1) to 0.73 (3×3), flat
//! beyond; worst-case gap to BISMO closes to ~0.5 LUT/op at large D_k.

use bismo::report::{f, Table};
use bismo::synth::{synth_bitparallel_dpu, synth_dpu};
use bismo::util::CsvWriter;

fn main() {
    let dks = [64u32, 128, 256, 512, 1024];
    let precisions = [(2u32, 1u32), (2, 2), (3, 2), (3, 3), (4, 4)];

    let mut table = Table::new(
        "Fig. 11 — LUT/bin.op: bit-serial vs bit-parallel DPUs",
        &["D_k", "bit-serial", "2x1", "2x2", "3x2", "3x3", "4x4"],
    );
    let mut csv = CsvWriter::new(
        "results/fig11_bitparallel.csv",
        &["dk", "bitserial", "p2x1", "p2x2", "p3x2", "p3x3", "p4x4"],
    );
    for &dk in &dks {
        let bs = synth_dpu(dk, 32).luts / (2.0 * dk as f64);
        let mut row = vec![format!("{dk}"), f(bs, 2)];
        let mut crow = vec![format!("{dk}"), format!("{bs}")];
        for &(w, a) in &precisions {
            let per_op =
                synth_bitparallel_dpu(w, a, dk).luts / (2.0 * (w * a * dk) as f64);
            row.push(f(per_op, 2));
            crow.push(format!("{per_op}"));
        }
        table.row(&row);
        csv.row(&crow);
    }
    table.print();
    let gap = synth_dpu(1024, 32).luts / 2048.0
        - synth_bitparallel_dpu(3, 3, 1024).luts / (2.0 * 9.0 * 1024.0);
    println!("worst-case gap BISMO vs 3x3 at D_k=1024: {gap:.2} LUT/op (paper: ~0.5)");
    println!("note: bit-parallel is fixed-precision; BISMO trades this gap for any-precision support");
    let path = csv.finish().expect("csv");
    println!("data -> {}", path.display());
}
