//! §Perf: the tiled, plane-fused kernel engine vs the naive baseline —
//! the headline software hot path. The same comparison (plus the JSON
//! trajectory) is available as `bismo bench`.

use bismo::baseline::{binary_ops, gemm_bitserial};
use bismo::bitmatrix::{BitSerialMatrix, IntMatrix};
use bismo::kernel::{gemm_tiled, gemm_tiled_with, KernelConfig, WorkerPool};
use bismo::partition::ShardPlan;
use bismo::util::bench::{report, BenchTimer};
use bismo::util::Rng;

fn main() {
    let mut rng = Rng::new(0x7173D);
    let threads = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4);

    // Precision sweep on a mid-size shape, then the 8-bit headline case
    // the perf-regression gate tracks.
    for (m, k, n, w, a) in [
        (128usize, 1024usize, 128usize, 1u32, 1u32),
        (128, 1024, 128, 4, 4),
        (96, 1000, 96, 3, 5), // ragged k, mixed precision
        (256, 2048, 256, 8, 8),
    ] {
        let am = IntMatrix::random(&mut rng, m, k, w, false);
        let bm = IntMatrix::random(&mut rng, k, n, a, false);
        let la = BitSerialMatrix::from_int(&am, w, false);
        let rb = BitSerialMatrix::from_int_transposed(&bm, a, false);
        assert_eq!(gemm_tiled(&la, &rb).unwrap(), gemm_bitserial(&la, &rb));
        let ops = binary_ops(m as u64, k as u64, n as u64, w, a) as f64;
        let t = BenchTimer::heavy();

        let s = t.run(|| gemm_bitserial(&la, &rb));
        let base_ns = s.median();
        report(
            &format!("baseline_{m}x{k}x{n}_w{w}a{a}_1t"),
            &s,
            Some((ops, "binop")),
        );
        let s = t.run(|| gemm_tiled(&la, &rb).unwrap());
        report(
            &format!("tiled_{m}x{k}x{n}_w{w}a{a}_1t"),
            &s,
            Some((ops, "binop")),
        );
        println!(
            "  -> tiled speedup {:.2}x over baseline (1 thread)",
            base_ns / s.median()
        );
        let s = t.run(|| {
            gemm_tiled_with(
                &la,
                &rb,
                &KernelConfig::default(),
                Some((WorkerPool::global(), threads)),
            )
            .unwrap()
        });
        report(
            &format!("tiled_{m}x{k}x{n}_w{w}a{a}_{threads}t"),
            &s,
            Some((ops, "binop")),
        );
    }

    // Sparse operands: zero planes cost the baseline full price and the
    // engine (ideally) nothing.
    let m = 128;
    let k = 2048;
    let n = 128;
    let am = IntMatrix::from_fn(m, k, |r, c| (((r + c) % 4) as i64) * 2); // LSB plane empty
    let bm = IntMatrix::from_fn(k, n, |r, c| ((r * c) % 2) as i64); // only LSB populated
    let la = BitSerialMatrix::from_int(&am, 6, false);
    let rb = BitSerialMatrix::from_int_transposed(&bm, 6, false);
    assert_eq!(gemm_tiled(&la, &rb).unwrap(), gemm_bitserial(&la, &rb));
    let t = BenchTimer::heavy();
    let s = t.run(|| gemm_bitserial(&la, &rb));
    let base_ns = s.median();
    report("baseline_sparse_128x2048x128_w6a6", &s, None);
    let s = t.run(|| gemm_tiled(&la, &rb).unwrap());
    report("tiled_sparse_128x2048x128_w6a6", &s, None);
    println!(
        "  -> zero-plane skip speedup {:.2}x (w6a6 with 4+5 empty planes)",
        base_ns / s.median()
    );

    // Tile-size ablation on the headline shape.
    let am = IntMatrix::random(&mut rng, 256, 2048, 8, false);
    let bm = IntMatrix::random(&mut rng, 2048, 256, 8, false);
    let la = BitSerialMatrix::from_int(&am, 8, false);
    let rb = BitSerialMatrix::from_int_transposed(&bm, 8, false);
    for (tm, tn) in [(4usize, 4usize), (8, 8), (16, 16), (8, 32)] {
        let cfg = KernelConfig {
            tile_m: tm,
            tile_n: tn,
            ..KernelConfig::default()
        };
        let s = t.run(|| gemm_tiled_with(&la, &rb, &cfg, None).unwrap());
        report(&format!("tiled_256x2048x256_w8a8_tile{tm}x{tn}"), &s, None);
    }

    // Shard scaling on the headline shape: the partition layer splits
    // the output and every shard runs as one pool lane — the engine
    // half of `bismo shard-bench`, without the serving layer around it.
    let expect = gemm_tiled(&la, &rb).unwrap();
    let ops = binary_ops(256, 2048, 256, 8, 8) as f64;
    let mut single_ns = 0.0;
    for shards in [1usize, 2, 4, 8] {
        let plan = ShardPlan::for_instances(256, 256, shards);
        let kcfg = KernelConfig::default();
        let run_sharded = || {
            let parts: Vec<IntMatrix> = {
                let shard_list = plan.shards();
                let slots: Vec<std::sync::Mutex<Option<IntMatrix>>> =
                    shard_list.iter().map(|_| std::sync::Mutex::new(None)).collect();
                WorkerPool::global().run_limited(shard_list.len(), shard_list.len(), &|i| {
                    let s = &shard_list[i];
                    let part = bismo::kernel::gemm_tiled_block(
                        &la,
                        &rb,
                        s.rows.clone(),
                        s.cols.clone(),
                        s.planes.clone(),
                        &kcfg,
                        None,
                    )
                    .unwrap();
                    *slots[i].lock().unwrap() = Some(part);
                });
                slots
                    .into_iter()
                    .map(|s| s.into_inner().unwrap().unwrap())
                    .collect()
            };
            plan.assemble(&parts).unwrap()
        };
        assert_eq!(run_sharded(), expect, "{shards} shard(s)");
        let s = t.run(run_sharded);
        let med = s.median();
        if shards == 1 {
            single_ns = med;
        }
        report(
            &format!("sharded_256x2048x256_w8a8_{shards}shards"),
            &s,
            Some((ops, "binop")),
        );
        println!(
            "  -> {shards} shard(s): {:.2}x vs single shard",
            single_ns / med
        );
    }
}
