//! Fig. 8: predicted vs actual LUT usage over the 34-design validation
//! sweep. Paper: 93.8% average accuracy, BRAM predictions 100% exact.

use bismo::costmodel::{validation_sweep, CostModel};
use bismo::report::{f, pct, Table};
use bismo::util::CsvWriter;

fn main() {
    let model = CostModel::fit_from_synth();
    println!(
        "fitted constants: alpha={:.2} beta={:.1} (paper 2.04 / 109.41)",
        model.alpha_dpu, model.beta_dpu
    );
    let pts = validation_sweep(&model);
    let mut table = Table::new(
        "Fig. 8 — predicted vs actual LUTs (34 designs)",
        &["Dm", "Dk", "Dn", "predicted", "actual", "error", "BRAM ok"],
    );
    let mut csv = CsvWriter::new(
        "results/fig08_costmodel.csv",
        &["dm", "dk", "dn", "predicted_luts", "actual_luts", "rel_error"],
    );
    let mut acc_sum = 0.0;
    let mut bram_exact = 0usize;
    for p in &pts {
        let ok = p.predicted_brams == p.actual_brams;
        bram_exact += ok as usize;
        acc_sum += p.lut_accuracy();
        table.rowf(&[
            &p.dm,
            &p.dk,
            &p.dn,
            &f(p.predicted_luts, 0),
            &f(p.actual_luts, 0),
            &pct(p.lut_error()),
            &ok,
        ]);
        csv.rowf(&[&p.dm, &p.dk, &p.dn, &p.predicted_luts, &p.actual_luts, &p.lut_error()]);
    }
    table.print();
    println!(
        "mean LUT accuracy: {} (paper: 93.8%)   BRAM exact: {}/{} (paper: 100%)",
        pct(acc_sum / pts.len() as f64),
        bram_exact,
        pts.len()
    );
    let path = csv.finish().expect("csv");
    println!("data -> {}", path.display());
}
