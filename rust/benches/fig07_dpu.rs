//! Fig. 7: DPU LUT usage and LUT-per-binary-op vs D_k.
//!
//! Paper: 2.8 LUT/op at D_k=32 falling to 1.07 at D_k=1024;
//! α_DPU = 2.04, β_DPU = 109.41; Fmax 300–350 MHz.

use bismo::costmodel::linear_fit;
use bismo::report::{f, Table};
use bismo::synth::synth_dpu;
use bismo::util::CsvWriter;

fn main() {
    let dks = [32u32, 64, 128, 256, 512, 1024];
    let mut table = Table::new(
        "Fig. 7 — DPU LUT usage & efficiency vs D_k",
        &["D_k", "LUTs", "LUT/bin.op", "Fmax (MHz)"],
    );
    let mut csv = CsvWriter::new(
        "results/fig07_dpu.csv",
        &["dk", "luts", "lut_per_op", "fmax_mhz"],
    );
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for &dk in &dks {
        let r = synth_dpu(dk, 32);
        let per_op = r.luts / (2.0 * dk as f64);
        table.rowf(&[&dk, &f(r.luts, 0), &f(per_op, 2), &f(r.fmax_mhz, 0)]);
        csv.rowf(&[&dk, &r.luts, &per_op, &r.fmax_mhz]);
        xs.push(dk as f64);
        ys.push(r.luts);
    }
    table.print();
    let (alpha, beta) = linear_fit(&xs, &ys).expect("D_k sweep is well-conditioned");
    println!("fitted: LUT_DPU = {alpha:.2}·D_k + {beta:.1}   (paper: 2.04·D_k + 109.41)");
    println!("paper: 2.8 LUT/op @ D_k=32 -> 1.07 @ D_k=1024; Fmax 300–350 MHz");
    let path = csv.finish().expect("csv");
    println!("data -> {}", path.display());
}
