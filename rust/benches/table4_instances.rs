//! Table IV: the six BISMO instances — LUT / BRAM / peak GOPS.

use bismo::arch::all_instances;
use bismo::arch::PYNQ_Z1;
use bismo::costmodel::CostModel;
use bismo::report::{f, pct, Table};
use bismo::synth::synth_instance;
use bismo::util::CsvWriter;

fn main() {
    let model = CostModel::paper();
    let paper_lut = [19545.0, 27740.0, 45573.0, 13352.0, 24202.0, 21755.0];
    let paper_bram = [121u64, 129, 129, 129, 129, 129];
    let mut table = Table::new(
        "Table IV — BISMO instances (model & virtual synthesis vs paper)",
        &[
            "#", "Dm", "Dk", "Dn", "LUT(model)", "LUT(synth)", "LUT(paper)", "util",
            "BRAM", "BRAM(paper)", "GOPS",
        ],
    );
    let mut csv = CsvWriter::new(
        "results/table4_instances.csv",
        &["id", "dm", "dk", "dn", "lut_model", "lut_synth", "brams", "gops"],
    );
    for (id, cfg) in all_instances() {
        let s = synth_instance(&cfg);
        let lut_model = model.lut_total(&cfg);
        let brams = model.bram_total(&cfg);
        let (util, _) = PYNQ_Z1.utilization(s.total_luts.round() as u64, brams);
        table.rowf(&[
            &id,
            &cfg.dm,
            &cfg.dk,
            &cfg.dn,
            &f(lut_model, 0),
            &f(s.total_luts, 0),
            &f(paper_lut[id as usize - 1], 0),
            &pct(util),
            &brams,
            &paper_bram[id as usize - 1],
            &f(cfg.peak_binary_gops(), 1),
        ]);
        csv.rowf(&[
            &id,
            &cfg.dm,
            &cfg.dk,
            &cfg.dn,
            &lut_model,
            &s.total_luts,
            &brams,
            &cfg.peak_binary_gops(),
        ]);
    }
    table.print();
    println!("paper GOPS column: 1638.4 / 3276.8 / 6553.6 / 1638.4 / 3276.8 / 3276.8 (exactly reproduced)");
    let path = csv.finish().expect("csv");
    println!("data -> {}", path.display());
}
