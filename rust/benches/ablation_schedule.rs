//! Ablation: the scheduler's design choices, quantified.
//!
//! DESIGN.md calls out three choices the paper leaves implicit:
//! (1) keeping RHS tile-columns resident vs streaming both operands,
//! (2) double-buffered fetch (stage overlap) vs serialized, and
//! (3) the result-buffer depth B_r.
//! This bench runs the same job under each choice and reports cycles +
//! DRAM traffic — the evidence behind the defaults.

use bismo::arch::{instance, BismoConfig};
use bismo::bitmatrix::IntMatrix;
use bismo::coordinator::{BismoContext, MatmulOptions, Precision};
use bismo::report::{f, Table};
use bismo::scheduler::Overlap;
use bismo::util::{CsvWriter, Rng};

fn run(cfg: BismoConfig, a: &IntMatrix, b: &IntMatrix, ov: Overlap) -> (u64, u64, u64) {
    let ctx = BismoContext::new(cfg).expect("ctx");
    let (_, rep) = ctx
        .matmul(a, b, Precision::unsigned(2, 2), MatmulOptions {
            overlap: ov,
            verify: true,
            ..Default::default()
        })
        .expect("matmul");
    (rep.cycles, rep.stats.bytes_fetched, rep.stats.execute_stall)
}

fn main() {
    let mut rng = Rng::new(0xAB1A);
    let (m, k, n) = (128usize, 4096usize, 128usize);
    let a = IntMatrix::random(&mut rng, m, k, 2, false);
    let b = IntMatrix::random(&mut rng, k, n, 2, false);

    let resident = instance(1); // big buffers → RhsResident mode
    let streaming = BismoConfig {
        bm: 512,
        bn: 512, // too small for 2 planes × 64 chunks × 16 tiles → Streaming
        ..instance(1)
    };

    let mut table = Table::new(
        &format!("schedule ablation — {m}x{k}x{n} w2a2 on 8x64x8 DPA"),
        &["variant", "cycles", "DRAM read (KiB)", "exec stall", "vs best"],
    );
    let mut csv = CsvWriter::new(
        "results/ablation_schedule.csv",
        &["variant", "cycles", "bytes_fetched"],
    );
    let cases = [
        ("rhs-resident + overlap", resident, Overlap::Full),
        ("rhs-resident serialized", resident, Overlap::None),
        ("streaming + overlap", streaming, Overlap::Full),
        ("streaming serialized", streaming, Overlap::None),
    ];
    let results: Vec<_> = cases
        .iter()
        .map(|(name, cfg, ov)| (*name, run(*cfg, &a, &b, *ov)))
        .collect();
    let best = results.iter().map(|(_, (c, _, _))| *c).min().unwrap();
    for (name, (cycles, bytes, stall)) in &results {
        table.rowf(&[
            name,
            cycles,
            &f(*bytes as f64 / 1024.0, 0),
            stall,
            &f(*cycles as f64 / best as f64, 2),
        ]);
        csv.rowf(&[name, cycles, bytes]);
    }
    table.print();
    println!("expected: RHS residency slashes DRAM traffic (operand reuse);");
    println!("overlap hides the remaining fetch latency — both choices compound.");

    // B_r sensitivity: result-buffer depth 1 vs 2 vs 4.
    let mut t2 = Table::new("result-buffer depth (B_r) sensitivity", &["B_r", "cycles"]);
    for br in [1u32, 2, 4] {
        let cfg = BismoConfig { br, ..resident };
        let (cycles, _, _) = run(cfg, &a, &b, Overlap::Full);
        t2.rowf(&[&br, &cycles]);
    }
    t2.print();
    println!("expected: B_r=2 suffices (result drain overlaps next tile's execute)");
    let path = csv.finish().expect("csv");
    println!("data -> {}", path.display());
}
