"""Tile selection for the MXU-form kernel under a VMEM budget.

The TPU analogue of the paper's B_m/B_n buffer-sizing decision (SSIII-B):
pick the largest MXU-aligned output tile (bm, bn) and k-block such that
the double-buffered working set fits VMEM, preferring square-ish tiles
(maximizes MACs per byte loaded, the same arithmetic-intensity argument
as the paper's D_k scaling).
"""

from .binary_matmul import vmem_footprint_bytes

# One TPU core's VMEM, minus headroom for spills/constants.
VMEM_BUDGET_BYTES = 14 * 2**20
# MXU systolic array dimension: tiles should be multiples of this.
MXU_DIM = 128


def aligned_candidates(limit: int, align: int = MXU_DIM):
    """Tile sizes to consider: multiples of `align` up to `limit`, and
    `limit` itself when smaller than one aligned step (small matrices
    fall back to 8-lane alignment)."""
    if limit < align:
        base = 8
        return [min(limit, base * i) for i in range(1, limit // base + 1)] or [limit]
    return [align * i for i in range(1, limit // align + 1)]


def choose_tiles(m: int, n: int, k: int, budget: int = VMEM_BUDGET_BYTES):
    """Pick (bm, bn, kblock) for `bitserial_matmul_mxu`.

    Returns the tiling with the highest arithmetic intensity
    (bm*bn / (bm+bn), i.e. MACs per plane byte streamed) whose
    double-buffered footprint fits the budget.
    """
    best = None
    for bm in aligned_candidates(m):
        for bn in aligned_candidates(n):
            # Largest k block that fits with this (bm, bn).
            kb = min(k, _max_kblock(bm, bn, budget))
            if kb < min(k, MXU_DIM if k >= MXU_DIM else k):
                continue  # degenerate: k slice thinner than one MXU pass
            fp = vmem_footprint_bytes(bm, bn, kb, 1)
            if fp > budget:
                continue
            intensity = (bm * bn) / (bm + bn)
            key = (intensity, kb, bm * bn)
            if best is None or key > best[0]:
                best = (key, (bm, bn, kb))
    if best is None:
        # Fall back to the smallest legal tile.
        return (min(8, m), min(8, n), min(k, 128))
    return best[1]


def _max_kblock(bm: int, bn: int, budget: int) -> int:
    """Largest k with 4*(2*bm*k + 2*bn*k + bm*bn) <= budget."""
    fixed = 4 * bm * bn
    per_k = 4 * 2 * (bm + bn)
    if budget <= fixed:
        return 0
    return (budget - fixed) // per_k
