"""Pure-jnp reference oracles for the Pallas kernels.

Everything here is straight-line jax.numpy with no Pallas, no tiling and
no cleverness: the ground truth that `binary_matmul.py` must match
bit-exactly (integer results) under pytest/hypothesis.
"""

import jax
import jax.numpy as jnp


def plane_weights(bits: int, signed: bool) -> jnp.ndarray:
    """Per-plane weights of a two's-complement decomposition.

    ``w[i] = 2**i`` except the MSB of a signed operand, which carries
    ``-2**(bits-1)`` (Algorithm 1 lines 5-7 of the paper).
    """
    w = 2 ** jnp.arange(bits, dtype=jnp.int64)
    if signed:
        w = w.at[bits - 1].multiply(-1)
    return w


def decompose(x: jnp.ndarray, bits: int, signed: bool) -> jnp.ndarray:
    """Bit-plane decomposition: int array [..., m, k] -> [bits, ..., m, k]
    of {0,1} int32 planes (two's complement within ``bits``)."""
    x = x.astype(jnp.int64)
    pattern = jnp.where(x < 0, x + (1 << bits), x)  # two's complement
    planes = [(pattern >> i) & 1 for i in range(bits)]
    return jnp.stack(planes).astype(jnp.int32)


def recompose(planes: jnp.ndarray, bits: int, signed: bool) -> jnp.ndarray:
    """Exact inverse of :func:`decompose`."""
    w = plane_weights(bits, signed)
    shape = (bits,) + (1,) * (planes.ndim - 1)
    return jnp.sum(planes.astype(jnp.int64) * w.reshape(shape), axis=0)


def int_matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Direct integer matmul oracle: the value every bit-serial path must
    reproduce exactly."""
    return jnp.matmul(a.astype(jnp.int64), b.astype(jnp.int64))


def binary_matmul_ref(l_plane: jnp.ndarray, r_plane_t: jnp.ndarray) -> jnp.ndarray:
    """One binary matmul: {0,1} planes, RHS transposed (n, k)."""
    return jnp.matmul(l_plane.astype(jnp.int64), r_plane_t.astype(jnp.int64).T)


def bitserial_matmul_ref(
    lhs: jnp.ndarray,
    rhs: jnp.ndarray,
    wbits: int,
    abits: int,
    lsigned: bool,
    rsigned: bool,
) -> jnp.ndarray:
    """Algorithm 1 executed literally: weighted sum of binary matmuls.

    ``lhs`` is (m, k) int, ``rhs`` is (k, n) int. Must equal
    :func:`int_matmul_ref` for in-range operands.
    """
    lp = decompose(lhs, wbits, lsigned)          # [w, m, k]
    rp = decompose(rhs.T, abits, rsigned)        # [a, n, k]
    wl = plane_weights(wbits, lsigned)
    wr = plane_weights(abits, rsigned)
    acc = jnp.zeros((lhs.shape[0], rhs.shape[1]), dtype=jnp.int64)
    for i in range(wbits):
        for j in range(abits):
            acc = acc + wl[i] * wr[j] * binary_matmul_ref(lp[i], rp[j])
    return acc


def pack_bits_u32(plane: jnp.ndarray) -> jnp.ndarray:
    """Pack a {0,1} plane (..., k) into uint32 words (..., ceil(k/32)),
    little-endian within each word — the DPU's bit-packed input format."""
    k = plane.shape[-1]
    kw = -(-k // 32)
    pad = kw * 32 - k
    p = jnp.pad(plane.astype(jnp.uint32), [(0, 0)] * (plane.ndim - 1) + [(0, pad)])
    p = p.reshape(p.shape[:-1] + (kw, 32))
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(p << shifts, axis=-1, dtype=jnp.uint32)


def popcount_matmul_ref(l_bits: jnp.ndarray, r_bits_t: jnp.ndarray) -> jnp.ndarray:
    """AND+popcount binary matmul on packed uint32 rows: the DPU
    operation. ``l_bits`` (m, kw), ``r_bits_t`` (n, kw) -> (m, n) int32."""
    anded = l_bits[:, None, :] & r_bits_t[None, :, :]
    return jnp.sum(jax.lax.population_count(anded), axis=-1).astype(jnp.int32)
