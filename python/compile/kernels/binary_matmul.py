"""Layer-1 Pallas kernels: the BISMO compute hot-spot on TPU terms.

The paper's DPU is AND + popcount feeding a weighted accumulator, sized
``D_k`` bits, replicated ``D_m x D_n`` times, fed from BRAM matrix
buffers. Two TPU-idiomatic formulations are provided (DESIGN.md
SSHardware-Adaptation):

* :func:`popcount_matmul` - the **direct port**: operands bit-packed
  into uint32 lanes, ``bitwise_and`` + ``lax.population_count`` on the
  VPU, reduced along k. The VMEM tile of packed words plays the role of
  the matrix buffer; ``D_k`` maps to the packed-lane tile width.

* :func:`bitserial_matmul_mxu` - the **rethink**: a binary matmul is a
  matmul of {0,1} matrices, which the MXU systolic array executes
  natively; bit-planes are fed as f32 {0,1} tiles to ``jnp.dot`` (exact
  up to 2^24), and the ``+-2^(i+j)`` weight is a scalar multiply fused
  into the accumulation - the paper's shift-and-negate unit. The grid's
  plane-pair dimension serializes exactly like Algorithm 1's outer
  loops, with the accumulator tile resident in VMEM across it.

Both are lowered with ``interpret=True`` (CPU PJRT cannot run Mosaic
custom-calls) and checked bit-exactly against `ref.py` by pytest and
hypothesis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ---------------------------------------------------------------------------
# Popcount form (direct DPU port).
# ---------------------------------------------------------------------------


def _popcount_kernel(l_ref, r_ref, o_ref):
    """One (bm, bn) output tile: AND + popcount over packed k words.

    ``l_ref``: (bm, kw) uint32, ``r_ref``: (bn, kw) uint32 - the matrix
    buffer contents for one DPU row/column group.
    """
    anded = l_ref[...][:, None, :] & r_ref[...][None, :, :]
    o_ref[...] = jnp.sum(
        jax.lax.population_count(anded).astype(jnp.int32), axis=-1
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def popcount_matmul(l_bits, r_bits_t, *, bm: int = 8, bn: int = 8):
    """Binary matmul on bit-packed operands.

    Args:
      l_bits: (m, kw) uint32 - LHS plane, k packed into 32-bit words.
      r_bits_t: (n, kw) uint32 - transposed RHS plane, same packing.
      bm, bn: VMEM tile sizes (the D_m/D_n analogue).

    Returns:
      (m, n) int32 popcount dot products.
    """
    m, kw = l_bits.shape
    n, _ = r_bits_t.shape
    if m % bm or n % bn:
        raise ValueError(f"shape ({m},{n}) not divisible by tile ({bm},{bn})")
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _popcount_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kw), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, kw), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(l_bits, r_bits_t)


# ---------------------------------------------------------------------------
# MXU form (bit-planes on the systolic array).
# ---------------------------------------------------------------------------


def _mxu_kernel(wl_ref, wr_ref, l_ref, r_ref, o_ref, *, abits: int):
    """Grid step (p, i, j): accumulate one weighted plane-pair product
    into output tile (i, j).

    ``l_ref``: (1, bm, k) f32 {0,1} - LHS plane p//abits, tile i.
    ``r_ref``: (1, bn, k) f32 {0,1} - RHS plane p%abits, tile j.
    ``wl_ref``/``wr_ref``: (1,) f32 plane weights (+-2^i).
    """
    p = pl.program_id(0)
    l = l_ref[0]
    r = r_ref[0]
    contrib = jax.lax.dot_general(
        l,
        r,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    w = wl_ref[0] * wr_ref[0]

    @pl.when(p == 0)
    def _init():
        o_ref[...] = w * contrib

    @pl.when(p > 0)
    def _acc():
        o_ref[...] += w * contrib


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def bitserial_matmul_mxu(l_planes, r_planes_t, wl, wr, *, bm: int = 8, bn: int = 8):
    """Weighted sum of binary matmuls on the MXU (Algorithm 1).

    Args:
      l_planes: (wbits, m, k) f32 {0,1} bit-planes of the LHS.
      r_planes_t: (abits, n, k) f32 {0,1} planes of the transposed RHS.
      wl: (wbits,) f32 plane weights (signed two's-complement weights).
      wr: (abits,) f32 plane weights.
      bm, bn: output tile sizes.

    Returns:
      (m, n) f32 - exact integers while |result| < 2^24.
    """
    wbits, m, k = l_planes.shape
    abits, n, _ = r_planes_t.shape
    if m % bm or n % bn:
        raise ValueError(f"shape ({m},{n}) not divisible by tile ({bm},{bn})")
    pairs = wbits * abits
    grid = (pairs, m // bm, n // bn)
    kernel = functools.partial(_mxu_kernel, abits=abits)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda p, i, j: (p // abits,)),
            pl.BlockSpec((1,), lambda p, i, j: (p % abits,)),
            pl.BlockSpec((1, bm, k), lambda p, i, j: (p // abits, i, 0)),
            pl.BlockSpec((1, bn, k), lambda p, i, j: (p % abits, j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda p, i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(wl, wr, l_planes, r_planes_t)


def vmem_footprint_bytes(bm: int, bn: int, k: int, pairs: int) -> int:
    """Estimated VMEM working set of one :func:`bitserial_matmul_mxu`
    grid step with double buffering: two (bm,k) + two (bn,k) f32 plane
    tiles in flight plus the resident (bm,bn) f32 accumulator.

    Used by the SSPerf notes in EXPERIMENTS.md; ``pairs`` does not grow
    the footprint (the accumulator is reused across the serial grid
    dimension) but is kept in the signature for the roofline notes.
    """
    del pairs
    return 4 * (2 * bm * k + 2 * bn * k + bm * bn)
