"""AOT export: lower the L2 entry points to HLO text artifacts.

Run once at build time (`make artifacts`); the rust coordinator loads
the resulting `artifacts/*.hlo.txt` through the PJRT C API and Python
never appears on the request path.

HLO **text** is the interchange format, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly. Lowered with
``return_tuple=True``; the rust side unwraps with ``to_tuple1()``.

Usage: ``python -m compile.aot --out ../artifacts``
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(fn, specs) -> str:
    """jit → lower → StableHLO → XlaComputation → HLO text."""
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_desc(spec) -> dict:
    return {"shape": list(spec.shape), "dtype": str(spec.dtype)}


def entries():
    """The artifact set: (name, fn, specs).

    Shapes used by the rust examples/benches; adding an entry here is
    the only step needed to expose a new computation to the runtime.
    """
    out = []

    # Coordinator verification matmul (quickstart shape).
    fn, specs = model.make_bitserial_matmul_fn(64, 256, 64, 4, 4, True, True)
    out.append(("bitserial_matmul_64x256x64_w4a4_ss", fn, specs))

    # Fig. 13 shape (precision sweep, modest size for CPU interpret).
    fn, specs = model.make_bitserial_matmul_fn(8, 2048, 8, 2, 2, False, False)
    out.append(("bitserial_matmul_8x2048x8_w2a2_uu", fn, specs))

    # Popcount-form kernel artifact (runtime kernel-verification path).
    fn, specs = model.make_binary_matmul_packed_fn(64, 64, 64)  # k = 2048
    out.append(("binary_matmul_popcount_64x2048x64", fn, specs))

    # End-to-end QNN forward (batch 16).
    fn, specs = model.make_qnn_mlp_fn(16)
    out.append(("qnn_mlp_b16_w4a2", fn, specs))

    return out


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--only", default=None, help="only regenerate artifacts whose name contains this"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {}
    for name, fn, specs in entries():
        if args.only and args.only not in name:
            continue
        text = to_hlo_text(fn, specs)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [spec_desc(s) for s in specs],
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
