"""Layer-2 JAX model: bit-serial matmul graphs and the quantized MLP.

This is the compute the rust coordinator executes through PJRT: the
functions here are lowered ONCE by `aot.py` to HLO text and never run
from Python at serving time. All integer work is expressed in int32 (the
overlay's accumulator width A = 32); the Pallas kernels of
`kernels/binary_matmul.py` sit at the hot spot.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.binary_matmul import bitserial_matmul_mxu, popcount_matmul


def bitserial_matmul(
    lhs: jnp.ndarray,
    rhs: jnp.ndarray,
    *,
    wbits: int,
    abits: int,
    lsigned: bool,
    rsigned: bool,
    bm: int = 8,
    bn: int = 8,
) -> jnp.ndarray:
    """Integer matmul via Algorithm 1 on the MXU-form Pallas kernel.

    Args:
      lhs: (m, k) int32, values within `wbits` (signed per `lsigned`).
      rhs: (k, n) int32, values within `abits`.

    Returns:
      (m, n) int32 product (exact while |result| < 2^24).
    """
    m, n = lhs.shape[0], rhs.shape[1]
    # Pad the output dims up to tile multiples (zero rows/cols contribute
    # zero planes), slice back after — the scheduler's partial tiles.
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    lhs_p = jnp.pad(lhs, ((0, mp - m), (0, 0)))
    rhs_p = jnp.pad(rhs, ((0, 0), (0, np_ - n)))
    lp = ref.decompose(lhs_p, wbits, lsigned).astype(jnp.float32)    # [w,m,k]
    rp = ref.decompose(rhs_p.T, abits, rsigned).astype(jnp.float32)  # [a,n,k]
    wl = ref.plane_weights(wbits, lsigned).astype(jnp.float32)
    wr = ref.plane_weights(abits, rsigned).astype(jnp.float32)
    out = bitserial_matmul_mxu(lp, rp, wl, wr, bm=bm, bn=bn)
    return out[:m, :n].astype(jnp.int32)


def binary_matmul_packed(l_bits: jnp.ndarray, r_bits_t: jnp.ndarray) -> jnp.ndarray:
    """One binary matmul on pre-packed uint32 planes (popcount form).

    The direct DPU analogue, exported for the runtime's kernel-level
    verification path.
    """
    return popcount_matmul(l_bits, r_bits_t)


def requantize(acc: jnp.ndarray, shift: int, out_bits: int) -> jnp.ndarray:
    """Integer-only requantization + ReLU: clip(acc >> shift, 0, 2^b-1).

    The standard integer-inference post-GEMM step; `shift` is fixed at
    export time (per-layer static scale).
    """
    shifted = jnp.right_shift(jnp.maximum(acc, 0), shift)
    return jnp.clip(shifted, 0, (1 << out_bits) - 1).astype(jnp.int32)


def qnn_mlp(
    x: jnp.ndarray,
    w1: jnp.ndarray,
    w2: jnp.ndarray,
    w3: jnp.ndarray,
    *,
    wbits: int = 4,
    abits: int = 2,
    shifts: tuple = (6, 4),
) -> jnp.ndarray:
    """Quantized 3-layer MLP forward pass (the paper's QNN motivation).

    Every GEMM runs through the bit-serial path. Activations are
    `abits`-bit unsigned, weights `wbits`-bit signed (two's complement),
    matching the precision regime of Park et al. / FINN that BISMO
    targets.

    Args:
      x:  (batch, 784) int32 in [0, 2^abits).
      w1: (784, 256) int32 signed `wbits`-bit.
      w2: (256, 256) int32 signed `wbits`-bit.
      w3: (256, 10) int32 signed `wbits`-bit.

    Returns:
      (batch, 10) int32 logits.
    """
    h = bitserial_matmul(
        x, w1, wbits=abits, abits=wbits, lsigned=False, rsigned=True
    )
    h = requantize(h, shifts[0], abits)
    h = bitserial_matmul(
        h, w2, wbits=abits, abits=wbits, lsigned=False, rsigned=True
    )
    h = requantize(h, shifts[1], abits)
    return bitserial_matmul(
        h, w3, wbits=abits, abits=wbits, lsigned=False, rsigned=True
    )


def make_bitserial_matmul_fn(m, k, n, wbits, abits, lsigned, rsigned):
    """Entry point factory for AOT export: fixes shapes + precision."""

    def fn(lhs, rhs):
        return (
            bitserial_matmul(
                lhs,
                rhs,
                wbits=wbits,
                abits=abits,
                lsigned=lsigned,
                rsigned=rsigned,
            ),
        )

    specs = (
        jax.ShapeDtypeStruct((m, k), jnp.int32),
        jax.ShapeDtypeStruct((k, n), jnp.int32),
    )
    return fn, specs


def make_qnn_mlp_fn(batch, wbits=4, abits=2):
    """AOT entry point for the full QNN forward pass."""

    def fn(x, w1, w2, w3):
        return (qnn_mlp(x, w1, w2, w3, wbits=wbits, abits=abits),)

    specs = (
        jax.ShapeDtypeStruct((batch, 784), jnp.int32),
        jax.ShapeDtypeStruct((784, 256), jnp.int32),
        jax.ShapeDtypeStruct((256, 256), jnp.int32),
        jax.ShapeDtypeStruct((256, 10), jnp.int32),
    )
    return fn, specs


def make_binary_matmul_packed_fn(m, kw, n):
    """AOT entry point for the popcount-form kernel (packed planes)."""

    def fn(l_bits, r_bits_t):
        return (binary_matmul_packed(l_bits, r_bits_t),)

    specs = (
        jax.ShapeDtypeStruct((m, kw), jnp.uint32),
        jax.ShapeDtypeStruct((n, kw), jnp.uint32),
    )
    return fn, specs
