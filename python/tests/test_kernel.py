"""L1 correctness: Pallas kernels vs the pure-jnp oracle, bit-exact.

Hypothesis sweeps shapes, precisions and signedness — the CORE
correctness signal for the compute layer.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.binary_matmul import (
    bitserial_matmul_mxu,
    popcount_matmul,
    vmem_footprint_bytes,
)


def _random_bits(rng, m, k):
    return rng.integers(0, 2, (m, k))


class TestPopcountForm:
    def test_small_exact(self):
        rng = np.random.default_rng(1)
        lp = _random_bits(rng, 8, 64)
        rp = _random_bits(rng, 8, 64)
        got = popcount_matmul(
            ref.pack_bits_u32(jnp.asarray(lp)), ref.pack_bits_u32(jnp.asarray(rp))
        )
        want = ref.binary_matmul_ref(jnp.asarray(lp), jnp.asarray(rp))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=25, deadline=None)
    @given(
        mt=st.integers(1, 4),
        nt=st.integers(1, 4),
        k=st.integers(1, 200),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_shapes(self, mt, nt, k, seed):
        rng = np.random.default_rng(seed)
        m, n = 8 * mt, 8 * nt
        lp = _random_bits(rng, m, k)
        rp = _random_bits(rng, n, k)
        got = popcount_matmul(
            ref.pack_bits_u32(jnp.asarray(lp)), ref.pack_bits_u32(jnp.asarray(rp))
        )
        want = ref.binary_matmul_ref(jnp.asarray(lp), jnp.asarray(rp))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_all_ones_hits_k(self):
        k = 130
        ones = jnp.ones((8, k), dtype=jnp.int32)
        got = popcount_matmul(ref.pack_bits_u32(ones), ref.pack_bits_u32(ones))
        np.testing.assert_array_equal(np.asarray(got), np.full((8, 8), k))

    def test_padding_bits_do_not_leak(self):
        # k = 33 packs into 2 words with 31 pad bits; they must stay 0.
        k = 33
        ones = jnp.ones((8, k), dtype=jnp.int32)
        got = popcount_matmul(ref.pack_bits_u32(ones), ref.pack_bits_u32(ones))
        np.testing.assert_array_equal(np.asarray(got), np.full((8, 8), k))

    def test_tile_mismatch_rejected(self):
        b = ref.pack_bits_u32(jnp.ones((9, 32), dtype=jnp.int32))
        with pytest.raises(ValueError, match="not divisible"):
            popcount_matmul(b, b, bm=8, bn=8)


class TestMxuForm:
    def _run(self, rng, m, k, n, w, a, ls, rs, bm=8, bn=8):
        lo_l = -(1 << (w - 1)) if ls else 0
        hi_l = (1 << (w - 1)) if ls else (1 << w)
        lo_r = -(1 << (a - 1)) if rs else 0
        hi_r = (1 << (a - 1)) if rs else (1 << a)
        lhs = rng.integers(lo_l, hi_l, (m, k))
        rhs = rng.integers(lo_r, hi_r, (k, n))
        lp = ref.decompose(jnp.asarray(lhs), w, ls).astype(jnp.float32)
        rp = ref.decompose(jnp.asarray(rhs.T), a, rs).astype(jnp.float32)
        wl = ref.plane_weights(w, ls).astype(jnp.float32)
        wr = ref.plane_weights(a, rs).astype(jnp.float32)
        got = bitserial_matmul_mxu(lp, rp, wl, wr, bm=bm, bn=bn)
        want = lhs.astype(np.int64) @ rhs.astype(np.int64)
        np.testing.assert_array_equal(np.asarray(got).astype(np.int64), want)

    def test_paper_fig1(self):
        l = jnp.array([[2, 0], [1, 3]], dtype=jnp.int32)
        r = jnp.array([[0, 1], [1, 2]], dtype=jnp.int32)
        lp = ref.decompose(l, 2, False).astype(jnp.float32)
        rp = ref.decompose(r.T, 2, False).astype(jnp.float32)
        wl = ref.plane_weights(2, False).astype(jnp.float32)
        got = bitserial_matmul_mxu(lp, rp, wl, wl, bm=2, bn=2)
        np.testing.assert_array_equal(
            np.asarray(got), np.array([[0.0, 2.0], [3.0, 7.0]])
        )

    @settings(max_examples=20, deadline=None)
    @given(
        mt=st.integers(1, 3),
        nt=st.integers(1, 3),
        k=st.integers(1, 96),
        w=st.integers(1, 6),
        a=st.integers(1, 6),
        ls=st.booleans(),
        rs=st.booleans(),
        seed=st.integers(0, 2**31),
    )
    def test_hypothesis_precisions(self, mt, nt, k, w, a, ls, rs, seed):
        rng = np.random.default_rng(seed)
        self._run(rng, 8 * mt, k, 8 * nt, w, a, ls, rs)

    def test_signed_extremes(self):
        # All-minimum signed values stress the negative MSB plane.
        for bits in (2, 4, 8):
            lo = -(1 << (bits - 1))
            m = k = n = 8
            lhs = np.full((m, k), lo)
            rhs = np.full((k, n), lo)
            lp = ref.decompose(jnp.asarray(lhs), bits, True).astype(jnp.float32)
            rp = ref.decompose(jnp.asarray(rhs.T), bits, True).astype(jnp.float32)
            wl = ref.plane_weights(bits, True).astype(jnp.float32)
            got = bitserial_matmul_mxu(lp, rp, wl, wl)
            want = lhs.astype(np.int64) @ rhs.astype(np.int64)
            np.testing.assert_array_equal(np.asarray(got).astype(np.int64), want)

    def test_different_tiles_same_answer(self):
        rng = np.random.default_rng(7)
        for (bm, bn) in [(8, 8), (16, 8), (8, 16), (16, 16)]:
            self._run(rng, 16, 50, 16, 3, 3, True, False, bm=bm, bn=bn)


class TestRefInternals:
    @settings(max_examples=30, deadline=None)
    @given(
        bits=st.integers(1, 16),
        signed=st.booleans(),
        seed=st.integers(0, 2**31),
    )
    def test_decompose_recompose_roundtrip(self, bits, signed, seed):
        rng = np.random.default_rng(seed)
        lo = -(1 << (bits - 1)) if signed else 0
        hi = (1 << (bits - 1)) if signed else (1 << bits)
        x = jnp.asarray(rng.integers(lo, hi, (5, 7)))
        planes = ref.decompose(x, bits, signed)
        assert set(np.unique(np.asarray(planes))) <= {0, 1}
        back = ref.recompose(planes, bits, signed)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))

    def test_bitserial_ref_equals_int_matmul(self):
        rng = np.random.default_rng(3)
        a = jnp.asarray(rng.integers(-8, 8, (5, 40)))
        b = jnp.asarray(rng.integers(0, 4, (40, 6)))
        got = ref.bitserial_matmul_ref(a, b, 4, 2, True, False)
        want = ref.int_matmul_ref(a, b)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_pack_bits_layout(self):
        # Bit i of word j covers column 32*j + i (little-endian).
        plane = jnp.zeros((1, 40), dtype=jnp.int32).at[0, 33].set(1).at[0, 0].set(1)
        packed = np.asarray(ref.pack_bits_u32(plane))
        assert packed.shape == (1, 2)
        assert packed[0, 0] == 1
        assert packed[0, 1] == 2

    def test_vmem_footprint_formula(self):
        # 8x8 tiles over k=2048: 2*(8*2048)*2*4B + 256B accumulator.
        b = vmem_footprint_bytes(8, 8, 2048, 16)
        assert b == 4 * (2 * 8 * 2048 + 2 * 8 * 2048 + 64)
        # A realistic TPU tiling (128x128 tiles, k blocked at 4096) must
        # fit VMEM (16 MiB) with double buffering.
        assert vmem_footprint_bytes(128, 128, 4096, 64) < 16 * 2**20
