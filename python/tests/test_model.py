"""L2 correctness: the full bit-serial matmul graph and the QNN MLP."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


class TestBitserialMatmul:
    @settings(max_examples=15, deadline=None)
    @given(
        mt=st.integers(1, 3),
        nt=st.integers(1, 3),
        k=st.integers(1, 128),
        w=st.integers(1, 5),
        a=st.integers(1, 5),
        ls=st.booleans(),
        rs=st.booleans(),
        seed=st.integers(0, 2**31),
    )
    def test_equals_int_matmul(self, mt, nt, k, w, a, ls, rs, seed):
        rng = np.random.default_rng(seed)
        m, n = 8 * mt, 8 * nt
        lo_l = -(1 << (w - 1)) if ls else 0
        hi_l = (1 << (w - 1)) if ls else (1 << w)
        lo_r = -(1 << (a - 1)) if rs else 0
        hi_r = (1 << (a - 1)) if rs else (1 << a)
        lhs = jnp.asarray(rng.integers(lo_l, hi_l, (m, k)), dtype=jnp.int32)
        rhs = jnp.asarray(rng.integers(lo_r, hi_r, (k, n)), dtype=jnp.int32)
        got = model.bitserial_matmul(
            lhs, rhs, wbits=w, abits=a, lsigned=ls, rsigned=rs
        )
        want = ref.int_matmul_ref(lhs, rhs)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestRequantize:
    def test_relu_clip_shift(self):
        acc = jnp.array([[-5, 0, 63, 64, 1000]], dtype=jnp.int32)
        out = model.requantize(acc, shift=4, out_bits=2)
        # -5 -> 0; 0 -> 0; 63>>4 = 3; 64>>4 = 4 clipped to 3; 1000 -> 3.
        np.testing.assert_array_equal(np.asarray(out), [[0, 0, 3, 3, 3]])

    def test_output_range(self):
        rng = np.random.default_rng(5)
        acc = jnp.asarray(rng.integers(-(2**20), 2**20, (4, 32)), dtype=jnp.int32)
        out = np.asarray(model.requantize(acc, shift=8, out_bits=3))
        assert out.min() >= 0 and out.max() <= 7


class TestQnnMlp:
    def _weights(self, rng, wbits=4):
        lo, hi = -(1 << (wbits - 1)), 1 << (wbits - 1)
        w1 = jnp.asarray(rng.integers(lo, hi, (784, 256)), dtype=jnp.int32)
        w2 = jnp.asarray(rng.integers(lo, hi, (256, 256)), dtype=jnp.int32)
        w3 = jnp.asarray(rng.integers(lo, hi, (256, 10)), dtype=jnp.int32)
        return w1, w2, w3

    def test_forward_shape_and_determinism(self):
        rng = np.random.default_rng(11)
        x = jnp.asarray(rng.integers(0, 4, (16, 784)), dtype=jnp.int32)
        w1, w2, w3 = self._weights(rng)
        y1 = model.qnn_mlp(x, w1, w2, w3)
        y2 = model.qnn_mlp(x, w1, w2, w3)
        assert y1.shape == (16, 10)
        assert y1.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))

    def test_matches_layerwise_reference(self):
        # Recompute the MLP with the pure reference matmul; logits must
        # agree exactly (integer-only pipeline).
        rng = np.random.default_rng(12)
        x = jnp.asarray(rng.integers(0, 4, (16, 784)), dtype=jnp.int32)
        w1, w2, w3 = self._weights(rng)
        got = model.qnn_mlp(x, w1, w2, w3, shifts=(6, 4))

        h = ref.int_matmul_ref(x, w1)
        h = model.requantize(h.astype(jnp.int32), 6, 2)
        h2 = ref.int_matmul_ref(h, w2)
        h2 = model.requantize(h2.astype(jnp.int32), 4, 2)
        want = ref.int_matmul_ref(h2, w3)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_zero_input_gives_zero_logits(self):
        rng = np.random.default_rng(13)
        w1, w2, w3 = self._weights(rng)
        x = jnp.zeros((16, 784), dtype=jnp.int32)
        y = model.qnn_mlp(x, w1, w2, w3)
        np.testing.assert_array_equal(np.asarray(y), np.zeros((16, 10)))
