"""Tile-tuning: selections must fit VMEM, align to the MXU, and remain
correct when plugged into the kernel."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.binary_matmul import bitserial_matmul_mxu, vmem_footprint_bytes
from compile.kernels.tuning import choose_tiles, MXU_DIM, VMEM_BUDGET_BYTES


class TestChooseTiles:
    def test_large_matmul_uses_mxu_tiles(self):
        bm, bn, kb = choose_tiles(4096, 4096, 8192)
        assert bm % MXU_DIM == 0 and bn % MXU_DIM == 0
        assert kb >= MXU_DIM
        assert vmem_footprint_bytes(bm, bn, kb, 1) <= VMEM_BUDGET_BYTES

    def test_small_matmul_fits(self):
        bm, bn, kb = choose_tiles(16, 16, 64)
        assert bm <= 16 and bn <= 16 and kb <= 64
        assert vmem_footprint_bytes(bm, bn, kb, 1) <= VMEM_BUDGET_BYTES

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.integers(8, 4096),
        n=st.integers(8, 4096),
        k=st.integers(8, 65536),
    )
    def test_always_within_budget(self, m, n, k):
        bm, bn, kb = choose_tiles(m, n, k)
        assert bm >= 1 and bn >= 1 and kb >= 1
        assert bm <= m and bn <= n and kb <= k
        assert vmem_footprint_bytes(bm, bn, kb, 1) <= VMEM_BUDGET_BYTES

    def test_bigger_budget_never_smaller_tiles(self):
        small = choose_tiles(2048, 2048, 4096, budget=2 * 2**20)
        large = choose_tiles(2048, 2048, 4096, budget=14 * 2**20)
        assert large[0] * large[1] >= small[0] * small[1]

    def test_selected_tiles_run_correctly(self):
        # Use a selection (scaled down to interpret-friendly sizes) in
        # the actual kernel and check exactness.
        m = n = 16
        k = 96
        bm, bn, kb = choose_tiles(m, n, k)
        assert kb == k, "k fits in one block at this size"
        rng = np.random.default_rng(0)
        lhs = rng.integers(0, 4, (m, k))
        rhs = rng.integers(-4, 4, (k, n))
        lp = ref.decompose(jnp.asarray(lhs), 2, False).astype(jnp.float32)
        rp = ref.decompose(jnp.asarray(rhs.T), 3, True).astype(jnp.float32)
        wl = ref.plane_weights(2, False).astype(jnp.float32)
        wr = ref.plane_weights(3, True).astype(jnp.float32)
        got = bitserial_matmul_mxu(lp, rp, wl, wr, bm=bm, bn=bn)
        want = lhs.astype(np.int64) @ rhs.astype(np.int64)
        np.testing.assert_array_equal(np.asarray(got).astype(np.int64), want)
