"""AOT export path: every entry lowers to parseable HLO text."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model


class TestLowering:
    def test_matmul_entry_lowers_to_hlo_text(self):
        fn, specs = model.make_bitserial_matmul_fn(8, 64, 8, 2, 2, False, False)
        text = aot.to_hlo_text(fn, specs)
        assert "HloModule" in text
        assert "ENTRY" in text
        # Tuple return for the rust side's to_tuple1().
        assert "tuple" in text.lower()

    def test_popcount_entry_lowers(self):
        fn, specs = model.make_binary_matmul_packed_fn(8, 4, 8)
        text = aot.to_hlo_text(fn, specs)
        assert "HloModule" in text
        # popcount survives lowering (CPU-executable op).
        assert "popcnt" in text or "popcount" in text.lower()

    def test_qnn_entry_lowers(self):
        fn, specs = model.make_qnn_mlp_fn(4)
        text = aot.to_hlo_text(fn, specs)
        assert "HloModule" in text
        assert "s32[4,10]" in text  # logits shape

    def test_entries_unique_names(self):
        names = [n for n, _, _ in aot.entries()]
        assert len(names) == len(set(names))


@pytest.mark.slow
class TestCliExport:
    def test_cli_writes_manifest(self, tmp_path):
        out = str(tmp_path)
        res = subprocess.run(
            [
                sys.executable,
                "-m",
                "compile.aot",
                "--out",
                out,
                "--only",
                "8x2048x8",
            ],
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            capture_output=True,
            text=True,
        )
        assert res.returncode == 0, res.stderr
        manifest = json.load(open(os.path.join(out, "manifest.json")))
        assert "bitserial_matmul_8x2048x8_w2a2_uu" in manifest
        entry = manifest["bitserial_matmul_8x2048x8_w2a2_uu"]
        assert entry["inputs"][0]["shape"] == [8, 2048]
        assert os.path.exists(os.path.join(out, entry["file"]))
