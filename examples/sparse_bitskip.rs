//! The paper's sparse/approximate extension (§III): "the ability to
//! ... dynamically skip bit positions for sparse or approximate
//! computing". The scheduler drops all-zero bit-planes, so operands
//! whose values use fewer effective bits finish proportionally faster
//! — with bit-exact results.

use bismo::arch::instance;
use bismo::bitmatrix::IntMatrix;
use bismo::coordinator::{BismoContext, MatmulOptions, Precision};
use bismo::report::{f, pct, Table};
use bismo::util::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = instance(2);
    let ctx = BismoContext::new(cfg)?;
    let (m, k, n) = (64usize, 4096usize, 64usize);
    let mut rng = Rng::new(0x5B17);

    // Operands declared 8-bit but only using `eff` low bits — a common
    // shape after per-layer quantization with conservative headroom.
    let mut table = Table::new(
        "bit-skip: declared 8x8-bit, varying effective bits (64x4096x64)",
        &["effective bits", "planes scheduled", "cycles", "vs dense", "exact"],
    );
    let am_dense = IntMatrix::random(&mut rng, m, k, 8, false);
    let bm_dense = IntMatrix::random(&mut rng, k, n, 8, false);
    let dense = ctx.matmul(
        &am_dense,
        &bm_dense,
        Precision::unsigned(8, 8),
        MatmulOptions::default(),
    )?;
    for eff in [8u32, 6, 4, 2, 1] {
        // Values limited to `eff` bits; upper planes are all zero.
        let am = IntMatrix::random(&mut rng, m, k, eff, false);
        let bm = IntMatrix::random(&mut rng, k, n, eff, false);
        let skip = ctx.matmul(
            &am,
            &bm,
            Precision::unsigned(8, 8), // declared precision stays 8x8
            MatmulOptions {
                bit_skip: true,
                ..Default::default()
            },
        )?;
        let exact = skip.0 == am.matmul(&bm);
        table.rowf(&[
            &eff,
            &format!("{}x{}", skip.1.lhs_planes, skip.1.rhs_planes),
            &skip.1.cycles,
            &pct(skip.1.cycles as f64 / dense.1.cycles as f64),
            &exact,
        ]);
        assert!(exact);
    }
    table.print();
    println!(
        "dense 8x8 reference: {} cycles ({} µs)",
        dense.1.cycles,
        f(dense.1.seconds * 1e6, 1)
    );
    println!("expected: cycles scale ~ (effective bits)^2 of the declared 64 plane pairs");
    Ok(())
}
