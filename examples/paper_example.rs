//! The paper's worked example (Fig. 1 / Table III / Fig. 5): the 2×2
//! 2-bit matrix multiplication, shown end to end — bit-plane
//! decomposition, the generated instruction queues, the simulated
//! timeline, and the result.

use bismo::arch::{BismoConfig, PYNQ_Z1};
use bismo::bitmatrix::dram::{DramImage, OperandLayout, ResultLayout};
use bismo::bitmatrix::{BitSerialMatrix, IntMatrix};
use bismo::scheduler::{compile, MatmulJob, Overlap};
use bismo::sim::Simulation;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // Fig. 1 operands.
    let l = IntMatrix::from_slice(2, 2, &[2, 0, 1, 3]);
    let r = IntMatrix::from_slice(2, 2, &[0, 1, 1, 2]);
    println!("L =\n{l}");
    println!("R =\n{r}");

    // Bit-plane decomposition (Fig. 1's weighted sum).
    let lb = BitSerialMatrix::from_int(&l, 2, false);
    for i in 0..2 {
        println!(
            "L[{i}] (weight {}): [{}{} / {}{}]",
            lb.plane_weight(i),
            lb.get_bit(i, 0, 0) as u8,
            lb.get_bit(i, 0, 1) as u8,
            lb.get_bit(i, 1, 0) as u8,
            lb.get_bit(i, 1, 1) as u8,
        );
    }

    // A 2×64×2 overlay (the example's DPA is as large as the matrices).
    let cfg = BismoConfig::small();
    let rb = BitSerialMatrix::from_int(&r.transpose(), 2, false);
    let lhs = OperandLayout::new(0, 2, 2, 2, cfg.dk);
    let rhs = OperandLayout::new(lhs.total_bytes(), 2, 2, 2, cfg.dk);
    let res = ResultLayout::new(lhs.total_bytes() + rhs.total_bytes(), 2, 2);
    let mut dram = DramImage::new((res.base + res.total_bytes()) as usize);
    lhs.store(&mut dram, &lb);
    rhs.store(&mut dram, &rb);
    let job = MatmulJob {
        m: 2,
        k: 2,
        n: 2,
        wbits: 2,
        abits: 2,
        lsigned: false,
        rsigned: false,
        lhs,
        rhs,
        res,
    };
    let prog = compile(&job, &cfg, Overlap::Full)?;

    // Table III: the three instruction queues.
    println!("{}", prog.disassemble());

    // Fig. 5: the timeline.
    let mut sim = Simulation::new(cfg, &PYNQ_Z1, dram)?;
    sim.enable_trace();
    let stats = sim.run(&prog)?;
    println!("Fig. 5 — execution timeline:");
    print!("{}", bismo::report::render_timeline(sim.trace(), 64));
    println!(
        "totals: {} cycles (fetch busy {}, execute busy {}, result busy {})",
        stats.cycles, stats.fetch_busy, stats.execute_busy, stats.result_busy
    );

    let p = res.load(&sim.dram);
    println!("P = L·R =\n{p}");
    assert_eq!(p, l.matmul(&r));
    println!("matches the paper's P = [[0,2],[3,7]] ✓");
    Ok(())
}
