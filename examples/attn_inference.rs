//! End-to-end driver: a quantized transformer encoder block served on
//! the overlay, with and without input-adaptive precision.
//!
//! The attention workload is a DAG of integer GEMMs with a distinct
//! precision per matrix — exactly the variable-precision serving the
//! bit-serial overlay is built for (work scales with the product of
//! operand bit widths):
//!
//! 1. build the `QnnAttn` demo preset (d_model 32, 4 heads, d_ff 48,
//!    3-bit activations, per-matrix weight widths w3/w2/w3/w2),
//! 2. prepare all six weight matrices once in a `bismo::api::Session`
//!    (weight-stationary packing cache, one entry per matrix at its
//!    own precision),
//! 3. serve requests of varying dynamic range, each gated bit-exact
//!    against the pure-i64 reference forward pass,
//! 4. re-serve the same requests under the exactness-preserving
//!    `RangeAdaptivePolicy`: identical output, fewer bit planes —
//!    the policy decision log shows where width was shed,
//! 5. quantify the win on the cycle-accurate simulator backend
//!    (static vs adaptive cycles for the same request),
//! 6. show the lossy `ClampPolicy` flagging its clips per decision.

use bismo::api::{Backend, Session, SessionConfig};
use bismo::qnn::{ClampPolicy, QnnAttn, RangeAdaptivePolicy};
use bismo::report::Table;
use bismo::util::Rng;
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Model (synthetic weights; the claim under test is bit-exact
    //    serving and adaptive-precision behaviour, not accuracy).
    let seq = 16usize;
    let model = QnnAttn::demo(0xA77B, seq);
    println!(
        "QnnAttn demo preset: d_model {}, {} heads, d_ff {}, a{} activations, {} GEMMs/pass",
        model.spec.d_model,
        model.spec.heads,
        model.spec.d_ff,
        model.abits,
        model.gemms_per_pass()
    );

    // 2. One session; prepare() packs all six weight matrices once.
    let session = Session::new(SessionConfig::default())?;
    let prepared = session.attn(&model).backend(Backend::Engine).prepare()?;

    // 3. Static serving, every request gated bit-exact. Requests cycle
    //    through dynamic ranges (1-, 2-, 3-bit activations) — the
    //    variation the adaptive policy will exploit in step 4.
    let mut rng = Rng::new(42);
    let inputs: Vec<_> = (0..6)
        .map(|i| model.random_input(&mut rng, seq, (i % model.abits as usize) as u32 + 1))
        .collect();
    let wall = Instant::now();
    for (i, x) in inputs.iter().enumerate() {
        let resp = prepared.execute(x)?;
        assert_eq!(
            resp.output,
            model.forward_reference(x)?,
            "served block != i64 reference (request {i})"
        );
        if i == 0 {
            assert!(
                resp.weights_cached(),
                "prepared weights serve the very first request from the cache"
            );
        }
    }
    let secs = wall.elapsed().as_secs_f64();
    println!(
        "served {} requests ({} tokens) bit-exactly on the engine backend: {:.0} tokens/s",
        inputs.len(),
        inputs.len() * seq,
        (inputs.len() * seq) as f64 / secs
    );

    // 4. The same requests under the input-adaptive range policy:
    //    output identical, declared bit planes shed per layer.
    let policy = RangeAdaptivePolicy::default();
    let mut static_bits = 0.0;
    let mut adaptive_bits = 0.0;
    let mut last_decisions = Vec::new();
    for (i, x) in inputs.iter().enumerate() {
        let stat = prepared.execute(x)?;
        let adap = prepared.execute_with_policy(x, &policy)?;
        assert_eq!(
            adap.output, stat.output,
            "range policy must be exactness-preserving (request {i})"
        );
        static_bits += stat.mean_lhs_bits();
        adaptive_bits += adap.mean_lhs_bits();
        last_decisions = adap.decisions;
    }
    println!(
        "adaptive precision, identical output: mean activation width {:.2} -> {:.2} bits",
        static_bits / inputs.len() as f64,
        adaptive_bits / inputs.len() as f64
    );
    let mut table = Table::new(
        "policy decisions (last request)",
        &["layer", "side", "base", "chosen", "clip", "reason"],
    );
    for d in &last_decisions {
        table.rowf(&[
            &d.layer,
            &d.side,
            &d.base_bits,
            &d.chosen_bits,
            &d.clip,
            &d.reason,
        ]);
    }
    table.print();

    // 5. The cycle-accurate view: the same low-range request, static
    //    vs adaptive, on the simulator backend.
    let sim = session.attn(&model).backend(Backend::Sim).prepare()?;
    let x = model.random_input(&mut rng, seq, 1);
    let want = model.forward_reference(&x)?;
    let stat = sim.execute(&x)?;
    let adap = sim.execute_with_policy(&x, &policy)?;
    assert_eq!(stat.output, want, "sim static != reference");
    assert_eq!(adap.output, want, "sim adaptive != reference");
    let (sc, ac) = (
        stat.sim_cycles().expect("sim backend carries reports"),
        adap.sim_cycles().expect("sim backend carries reports"),
    );
    println!(
        "sim cycles for a 1-bit-range request: static {sc}, adaptive {ac} ({:.2}x fewer)",
        sc as f64 / ac.max(1) as f64
    );

    // 6. A lossy policy is allowed — but every clip is flagged.
    let clamped = prepared.execute_with_policy(&x, &ClampPolicy { bits: 1 })?;
    let clips = clamped.decisions.iter().filter(|d| d.clip).count();
    println!(
        "ClampPolicy{{bits: 1}} on the same request: {} of {} decisions clipped (lossy, flagged)",
        clips,
        clamped.decisions.len()
    );

    let cs = session.cache_stats();
    println!(
        "packing cache: {} hits / {} misses across static, adaptive and sim serving",
        cs.hits, cs.misses
    );
    println!("attn_inference OK");
    Ok(())
}
