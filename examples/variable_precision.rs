//! The paper's headline flexibility claim: runtime scales with the
//! precision the application actually needs — one overlay, any
//! precision (contrast with a fixed-precision accelerator that always
//! pays for its maximum).

use bismo::arch::instance;
use bismo::bitmatrix::IntMatrix;
use bismo::coordinator::{BismoContext, MatmulOptions, Precision};
use bismo::report::{f, Table};
use bismo::util::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = instance(2);
    let ctx = BismoContext::new(cfg)?;
    let (m, k, n) = (64usize, 4096usize, 64usize);
    let mut rng = Rng::new(0xFACE);

    let mut table = Table::new(
        "variable precision on one overlay (64x4096x64, instance #2)",
        &["precision", "cycles", "µs", "vs binary", "w*a", "effective GOPS"],
    );
    let mut binary = 0u64;
    for (w, a) in [(1u32, 1u32), (2, 2), (3, 3), (4, 4), (5, 5), (6, 6), (8, 8)] {
        let am = IntMatrix::random(&mut rng, m, k, w, false);
        let bm = IntMatrix::random(&mut rng, k, n, a, false);
        let opts = MatmulOptions {
            verify: true,
            ..Default::default()
        };
        let (_, rep) = ctx.matmul(&am, &bm, Precision::unsigned(w, a), opts)?;
        if w == 1 {
            binary = rep.cycles;
        }
        table.rowf(&[
            &format!("{w}x{a}-bit"),
            &rep.cycles,
            &f(rep.seconds * 1e6, 1),
            &f(rep.cycles as f64 / binary as f64, 2),
            &(w * a),
            &f(rep.gops, 1),
        ]);
    }
    table.print();
    println!("expected: 'vs binary' tracks (slightly below) w*a — precision is pay-as-you-go");
    Ok(())
}
