//! The paper's headline flexibility claim: runtime scales with the
//! precision the application actually needs — one overlay, any
//! precision (contrast with a fixed-precision accelerator that always
//! pays for its maximum).
//!
//! Routed through the `bismo::api` facade: one [`Session`] owns the
//! worker pool and backends, a [`bismo::api::MatmulBuilder`] per
//! precision submits asynchronously, and all seven jobs drain
//! concurrently as one dynamic micro-batch on the simulator backend;
//! every result is verified against the CPU bit-serial oracle (the
//! builder's `verify(true)`) and asserted against the i64 reference
//! before being reported.

use bismo::api::{Backend, Precision, Session, SessionConfig};
use bismo::arch::try_instance;
use bismo::bitmatrix::IntMatrix;
use bismo::report::{f, Table};
use bismo::util::Rng;
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let cfg = try_instance(2)?;
    let session = Session::new(SessionConfig {
        workers: 4,
        overlay: cfg,
        ..Default::default()
    })?;
    let (m, k, n) = (64usize, 4096usize, 64usize);
    let mut rng = Rng::new(0xFACE);

    // Submit everything asynchronously, then collect in order: the
    // session's serving layer forms micro-batches from whatever is
    // queued.
    let precisions = [(1u32, 1u32), (2, 2), (3, 3), (4, 4), (5, 5), (6, 6), (8, 8)];
    let mut jobs = Vec::new();
    for &(w, a) in &precisions {
        let am = Arc::new(IntMatrix::random(&mut rng, m, k, w, false));
        let bm = Arc::new(IntMatrix::random(&mut rng, k, n, a, false));
        let handle = session
            .matmul(Precision::try_new(w, a, false, false)?)
            .backend(Backend::Sim)
            .verify(true)
            .submit(am.clone(), bm.clone())?;
        jobs.push((w, a, am, bm, handle));
    }

    let mut table = Table::new(
        "variable precision on one overlay (64x4096x64, instance #2, via bismo::api::Session)",
        &["precision", "cycles", "µs", "vs binary", "w*a", "effective GOPS"],
    );
    let mut binary = 0u64;
    for (w, a, am, bm, handle) in jobs {
        let resp = handle.wait()?;
        // The facade must agree exactly with the i64 reference.
        assert_eq!(
            resp.result,
            am.matmul(&bm),
            "session result mismatch at {w}x{a}-bit"
        );
        let rep = resp
            .report
            .expect("sim backend always carries a RunReport");
        if w == 1 {
            binary = rep.cycles;
        }
        table.rowf(&[
            &format!("{w}x{a}-bit"),
            &rep.cycles,
            &f(rep.seconds * 1e6, 1),
            &f(rep.cycles as f64 / binary as f64, 2),
            &(w * a),
            &f(rep.gops, 1),
        ]);
    }
    table.print();
    println!("expected: 'vs binary' tracks (slightly below) w*a — precision is pay-as-you-go");
    println!("all 7 results verified against the CPU oracle and the i64 reference");
    Ok(())
}
