//! End-to-end driver: quantized-MLP inference served on the overlay
//! through the asynchronous serving layer.
//!
//! The full workflow the paper motivates (QNN inference with
//! per-application precision):
//!
//! 1. generate a synthetic 784-d digit dataset (MNIST stand-in),
//! 2. train a float MLP (784-256-256-10) in-crate with SGD,
//! 3. post-training-quantize to w4 (weights) / a2 (activations),
//! 4. serve batched inference through a `bismo::api::Session` where
//!    EVERY GEMM runs on the cycle-accurate overlay simulator (Table IV
//!    instance #2) — layer weights are weight-stationary, so from the
//!    second batch on the service's packing cache hands each layer its
//!    pre-packed weights without repacking,
//! 5. assert logits bit-exactly against the integer reference on every
//!    batch (and against the AOT-compiled JAX/Pallas artifact via PJRT
//!    when the `xla` feature is enabled),
//! 6. report accuracy (float vs quantized), per-layer cycles,
//!    latency/throughput at 200 MHz, and the cache's repack-avoidance.
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use bismo::api::{Backend, Session, SessionConfig};
use bismo::arch::try_instance;
use bismo::coordinator::RequestOptions;
use bismo::qnn::{FloatMlp, QnnMlp, SyntheticDigits};
use bismo::report::{f, pct, Table};
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Data.
    let data = SyntheticDigits::generate(42, 2000, 400, 0.18);
    println!(
        "dataset: {} train / {} test, dim {}",
        data.train_x.len(),
        data.test_x.len(),
        data.dim
    );

    // 2. Float training.
    let mut mlp = FloatMlp::new(7, [784, 256, 256, 10]);
    let t0 = Instant::now();
    for epoch in 0..4 {
        let loss = mlp.train_epoch(&data.train_x, &data.train_y, 0.02, epoch);
        println!("epoch {epoch}: mean loss {loss:.4}");
    }
    let float_acc = mlp.accuracy(&data.test_x, &data.test_y);
    println!(
        "float accuracy: {} (trained in {:.1?})",
        pct(float_acc),
        t0.elapsed()
    );

    // 3. Quantize (w4 a2, the regime the paper's QNN motivation cites).
    let q = QnnMlp::from_float(&mlp, 4, 2, (6, 4));
    let xq_all = q.quantize_input(&data.test_x);
    let ref_logits = q.forward_reference(&xq_all);
    let q_acc = QnnMlp::accuracy(&ref_logits, &data.test_y);
    println!("quantized (w4/a2) accuracy: {}", pct(q_acc));

    // 4. Serve batches through the api facade (sim backend: every
    //    GEMM is simulated cycle-accurately on instance #2). The
    //    Session owns the worker pool, both backends and the
    //    weight-stationary packing cache.
    let cfg = try_instance(2)?;
    let session = Session::new(SessionConfig {
        workers: 4,
        max_batch: 8,
        overlay: cfg,
        ..Default::default()
    })?;
    let svc = session.service();
    let opts = RequestOptions {
        backend: Backend::Sim,
        ..Default::default()
    };
    let batch = 16usize;
    let mut table = Table::new(
        "per-layer overlay cost (batch 16, instance #2 @ 200 MHz, via BismoService)",
        &["layer", "shape", "cycles", "GOPS", "efficiency"],
    );
    let mut total_cycles = 0u64;
    let mut correct = 0usize;
    let mut served = 0usize;
    let mut batches_served = 0usize;
    let wall = Instant::now();
    for (bi, chunk) in data.test_x.chunks(batch).take(8).enumerate() {
        batches_served += 1;
        let x = q.quantize_input(chunk);
        let (logits, responses) = q.forward_on_service(svc, x.clone(), opts)?;
        // The serving layer must be bit-exact against the integer
        // reference on every batch.
        assert_eq!(
            logits,
            q.forward_reference(&x),
            "service logits != integer reference (batch {bi})"
        );
        let labels = &data.test_y[bi * batch..bi * batch + chunk.len()];
        correct += QnnMlp::predictions(&logits)
            .iter()
            .zip(labels)
            .filter(|(p, y)| p == y)
            .count();
        served += chunk.len();
        let reports: Vec<_> = responses
            .iter()
            .map(|r| r.report.as_ref().expect("sim backend carries reports"))
            .collect();
        if bi == 0 {
            let shapes = ["16x784x256", "16x256x256", "16x256x10"];
            for (li, rep) in reports.iter().enumerate() {
                table.rowf(&[
                    &(li + 1),
                    &shapes[li],
                    &rep.cycles,
                    &f(rep.gops, 1),
                    &pct(rep.efficiency),
                ]);
            }
            assert!(
                responses.iter().all(|r| !r.rhs_cached),
                "first batch packs every layer's weights"
            );
        } else {
            assert!(
                responses.iter().all(|r| r.rhs_cached),
                "weight-stationary reuse: later batches hit the packing cache"
            );
        }
        total_cycles += reports.iter().map(|r| r.cycles).sum::<u64>();
    }
    table.print();
    let batches = batches_served as f64;
    let secs_per_batch = (total_cycles as f64 / batches) / (cfg.fclk_mhz as f64 * 1e6);
    println!(
        "served {} inferences in {} batches: overlay accuracy {} (reference {})",
        served,
        batches,
        pct(correct as f64 / served as f64),
        pct(q_acc)
    );
    println!(
        "simulated latency: {:.2} ms/batch -> {:.0} inferences/s at {} MHz  (host wall {:.1?})",
        secs_per_batch * 1e3,
        batch as f64 / secs_per_batch,
        cfg.fclk_mhz,
        wall.elapsed()
    );
    let cs = session.cache_stats();
    println!(
        "packing cache: {} hits / {} misses ({} entries, {} KiB resident) — \
         {} of {} batches served their weights without repacking",
        cs.hits,
        cs.misses,
        session.cache_entries(),
        session.cache_bytes() / 1024,
        batches_served.saturating_sub(1),
        batches_served
    );

    // 5. PJRT cross-check on the first batch (needs the `xla` cargo
    //    feature and `make artifacts`).
    #[cfg(feature = "xla")]
    {
        use bismo::bitmatrix::IntMatrix;
        use bismo::runtime::Runtime;
        use std::path::Path;
        let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if artifacts.join("manifest.json").exists() {
            let rt = Runtime::new(&artifacts)?;
            let exe = rt.load("qnn_mlp_b16_w4a2")?;
            let x = q.quantize_input(&data.test_x[..16]);
            let inputs: [&IntMatrix; 4] = [&x, &q.w1, &q.w2, &q.w3];
            let jax_logits = exe.run_i32(&inputs)?;
            let (service_logits, _) = q.forward_on_service(svc, x.clone(), opts)?;
            assert_eq!(jax_logits, service_logits, "JAX artifact vs serving layer");
            println!("PJRT cross-check: JAX/Pallas QNN artifact agrees bit-exactly ✓");
        }
    }

    println!("qnn_inference OK");
    Ok(())
}
