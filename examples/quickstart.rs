//! Quickstart: one matrix multiplication through the whole stack.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Runs a 64×256×64 signed 4×4-bit matmul on the overlay (pack →
//! schedule → simulate), verifies the result against the i64 reference
//! AND against the AOT-compiled JAX/Pallas artifact executed through
//! PJRT, and prints the run report.

use bismo::arch::instance;
use bismo::bitmatrix::IntMatrix;
use bismo::coordinator::{BismoContext, MatmulOptions, Precision};
use bismo::report::{f, pct};
use bismo::util::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. An overlay instance (Table IV #1: 8×64×8 DPA on the PYNQ-Z1).
    let cfg = instance(1);
    let ctx = BismoContext::new(cfg)?;
    println!(
        "overlay: {}x{}x{} DPA @ {} MHz  (peak {} binary GOPS)",
        cfg.dm,
        cfg.dk,
        cfg.dn,
        cfg.fclk_mhz,
        f(cfg.peak_binary_gops(), 1)
    );

    // 2. Random signed 4-bit operands.
    let mut rng = Rng::new(42);
    let a = IntMatrix::random(&mut rng, 64, 256, 4, true);
    let b = IntMatrix::random(&mut rng, 256, 64, 4, true);

    // 3. Multiply on the overlay with verification enabled.
    let opts = MatmulOptions {
        verify: true,
        ..Default::default()
    };
    let (p, rep) = ctx.matmul(&a, &b, Precision::signed(4, 4), opts)?;
    assert_eq!(p, a.matmul(&b), "overlay result vs i64 reference");
    println!(
        "overlay run: {} cycles = {:.1} µs  |  {} GOPS ({} of peak)  |  {:.2} W -> {} GOPS/W",
        rep.cycles,
        rep.seconds * 1e6,
        f(rep.gops, 1),
        pct(rep.efficiency),
        rep.power_w,
        f(rep.gops_per_w, 1)
    );
    println!(
        "instructions: {} fetch / {} execute / {} result (+{} syncs)",
        rep.instructions.fetch_runs,
        rep.instructions.execute_runs,
        rep.instructions.result_runs,
        rep.instructions.waits + rep.instructions.signals
    );

    // 4. Cross-check against the AOT-compiled JAX/Pallas artifact
    //    (needs the `xla` cargo feature and `make artifacts`).
    #[cfg(feature = "xla")]
    {
        use bismo::runtime::Runtime;
        use std::path::Path;
        let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if artifacts.join("manifest.json").exists() {
            let rt = Runtime::new(&artifacts)?;
            let exe = rt.load("bitserial_matmul_64x256x64_w4a4_ss")?;
            let jax_out = exe.run_i32(&[&a, &b])?;
            assert_eq!(jax_out, p, "PJRT artifact vs overlay");
            println!("PJRT cross-check: JAX/Pallas artifact agrees bit-exactly ✓");
        } else {
            println!("(run `make artifacts` to enable the PJRT cross-check)");
        }
    }
    #[cfg(not(feature = "xla"))]
    println!("(build with --features xla for the PJRT cross-check)");
    println!("quickstart OK");
    Ok(())
}
