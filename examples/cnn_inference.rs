//! End-to-end driver: quantized-CNN inference served on the overlay
//! through the convolution lowering layer.
//!
//! The convolution-dominated workload the paper motivates BISMO with
//! (QNN inference, Umuroglu et al. 2018; conv-to-GEMM lowering as the
//! throughput driver, Umuroglu et al. 2019):
//!
//! 1. build the 28×28 `QnnCnn` preset (conv–pool–conv–pool–dense,
//!    per-layer weight precisions w3/w2/w3 at 2-bit activations),
//! 2. prepare every layer's lowered weights once in a
//!    `bismo::api::Session` (weight-stationary packing cache),
//! 3. serve batched inference with the conv layers lowered to
//!    bit-serial GEMM — packed-im2col planes built directly from the
//!    input tensor, no dense patch matrix,
//! 4. assert logits bit-exactly against the naive direct-convolution
//!    reference on every batch, and assert the kn2row lowering agrees
//!    with im2col,
//! 5. exercise the per-layer variable-precision claim: the same
//!    resident conv2 weights served at a wider declared precision,
//! 6. report throughput, per-layer sim cycles and cache reuse.

use bismo::api::{Backend, LoweringMode, Precision, Session, SessionConfig};
use bismo::qnn::{QnnCnn, SyntheticDigits};
use bismo::report::Table;
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Model + data (synthetic 28×28 digits; the claim under test is
    //    bit-exactness of the lowered serving path, not accuracy).
    let cnn = QnnCnn::digits(0xC22);
    let data = SyntheticDigits::generate(42, 10, 128, 0.18);
    println!(
        "QnnCnn digits preset: conv 1->8 (w3) -> pool -> conv 8->16 (w2) -> pool -> \
         fc 784x10 (w3), a{} activations",
        cnn.abits
    );

    // 2. One session serves every layer of every inference.
    let session = Session::new(SessionConfig::default())?;
    let served = cnn.serve(&session, LoweringMode::Im2col, Backend::Engine)?;

    // 3./4. Batched engine serving, every batch gated bit-exact.
    let batch = 16usize;
    let batches = 4usize;
    let wall = Instant::now();
    let mut served_count = 0usize;
    for (bi, chunk) in data.test_x.chunks(batch).take(batches).enumerate() {
        let x = cnn.quantize_input(chunk);
        let (logits, gemms) = served.infer(&x)?;
        assert_eq!(
            logits,
            cnn.forward_reference(&x),
            "served logits != direct-conv reference (batch {bi})"
        );
        if bi == 0 {
            assert!(
                gemms.iter().all(|g| g.rhs_cached),
                "prepared weights serve the very first batch from the cache"
            );
        }
        served_count += x.n;
    }
    let secs = wall.elapsed().as_secs_f64();
    println!(
        "served {served_count} inferences in {batches} batches on the engine backend: \
         {:.0} inferences/s (host wall)",
        served_count as f64 / secs
    );

    // The kn2row lowering computes the identical result through a
    // different GEMM decomposition (9 taps per conv layer).
    let x = cnn.quantize_input(&data.test_x[..8]);
    let kn_served = cnn.serve(&session, LoweringMode::Kn2row, Backend::Engine)?;
    let (kn_logits, kn_gemms) = kn_served.infer(&x)?;
    assert_eq!(kn_logits, cnn.forward_reference(&x), "kn2row != reference");
    println!(
        "kn2row lowering agrees bit-exactly ({} GEMMs vs 3 for im2col)",
        kn_gemms.len()
    );

    // 5. Variable precision per layer: the same resident conv2 weights
    //    served at a wider declared precision change nothing.
    let wider = Precision {
        wbits: 3,
        abits: 4,
        lsigned: false,
        rsigned: true,
    };
    let (base_logits, _) = served.infer(&x)?;
    let (wide_logits, _) = served.infer_with_conv2(&x, wider)?;
    assert_eq!(base_logits, wide_logits, "declared headroom changed logits");
    println!("per-layer precision override (conv2 at w4/a3): logits identical");

    // 6. Cycle-accurate view of one small batch, per layer.
    let sim_served = cnn.serve(&session, LoweringMode::Im2col, Backend::Sim)?;
    let xs = cnn.quantize_input(&data.test_x[..4]);
    let (sim_logits, sim_gemms) = sim_served.infer(&xs)?;
    assert_eq!(sim_logits, cnn.forward_reference(&xs), "sim != reference");
    let mut table = Table::new(
        "per-layer overlay cost (batch 4, sim backend)",
        &["layer", "gemm shape", "cycles"],
    );
    let names = ["conv1", "conv2", "fc"];
    let shapes = [
        cnn.conv1.spec.gemm_shape(4),
        cnn.conv2.spec.gemm_shape(4),
        bismo::partition::GemmShape {
            m: 4,
            k: cnn.fc.rows,
            n: cnn.fc.cols,
        },
    ];
    for (i, g) in sim_gemms.iter().enumerate() {
        let rep = g.report.as_ref().expect("sim backend carries reports");
        table.rowf(&[&names[i], &shapes[i], &rep.cycles]);
    }
    table.print();

    let cs = session.cache_stats();
    println!(
        "packing cache: {} hits / {} misses over every lowering mode and precision served",
        cs.hits, cs.misses
    );
    println!("cnn_inference OK");
    Ok(())
}
